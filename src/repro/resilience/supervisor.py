"""Worker-death classification and restart budgets for elastic pools.

The ``MultiprocessLauncher`` monitor consults this module when a child
process dies: the exit is classified, and — when a ``RestartPolicy`` is
attached to the program and the dead node is a ``role="worker"`` replica —
the worker is respawned with exponential backoff instead of failing the
whole run.  Stateful ``role="service"`` nodes (replay shards, counter,
learner replicas) are covered by the same policy through
``repro.resilience.failover.ServiceWatchdog``: their deaths are classified
with the same ``classify_exit`` and charged against the same per-node
budget, but a respawn RESTORES the service's periodic snapshot and
re-binds its courier server at the same address (workers respawn fresh
from their spawn payloads — they are stateless by design).  A service
whose budget is exhausted stays fail-fast.

Classification:

- ``SHUTDOWN`` — exit code 0, or any death while a stop was already in
  flight.  Never restarted.
- ``PREEMPTED`` — killed by a signal (negative exit code): the scheduler
  took the machine back.  Restartable.
- ``CRASH`` — any other nonzero exit: the worker itself failed.
  Restartable (up to the budget), because single-worker crashes in a
  fleet are routine (OOM, flaky env) and the learner stream must survive
  them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

CRASH = "crash"
PREEMPTED = "preempted"
SHUTDOWN = "shutdown"


def classify_exit(exitcode: Optional[int], *, stopping: bool = False) -> str:
    """Classify a dead worker's exit code.

    ``stopping`` marks deaths observed after the launcher initiated its own
    stop — those are shutdown noise regardless of the code (a worker killed
    mid-RPC can die nonzero during teardown).
    """
    if stopping or exitcode == 0:
        return SHUTDOWN
    if exitcode is not None and exitcode < 0:
        return PREEMPTED
    return CRASH


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """How the supervisor respawns dead nodes (worker replicas and, via the
    service watchdog, stateful services).

    ``max_restarts`` is a PER-NODE budget; once a node exhausts it, its
    next death is fail-fast (the run stops).
    Backoff for restart number k (0-based) is
    ``min(backoff_base_s * backoff_factor**k, backoff_max_s)``.
    """

    max_restarts: int = 3
    backoff_base_s: float = 0.1
    backoff_factor: float = 2.0
    backoff_max_s: float = 5.0
    restart_on: Tuple[str, ...] = (CRASH, PREEMPTED)

    def __post_init__(self):
        if self.max_restarts < 0:
            raise ValueError("max_restarts must be >= 0")
        if self.backoff_base_s < 0 or self.backoff_max_s < 0:
            raise ValueError("backoff durations must be >= 0")
        if self.backoff_factor < 1.0:
            raise ValueError("backoff_factor must be >= 1.0")
        for kind in self.restart_on:
            if kind not in (CRASH, PREEMPTED, SHUTDOWN):
                raise ValueError(f"unknown exit kind {kind!r}")

    def backoff(self, restart_index: int) -> float:
        return min(self.backoff_base_s * self.backoff_factor ** restart_index,
                   self.backoff_max_s)

    def should_restart(self, kind: str, restarts_so_far: int) -> bool:
        return kind in self.restart_on and restarts_so_far < self.max_restarts
