"""Service failover: snapshot, kill-detect, and respawn for stateful
parent-resident services.

PR 8 made *workers* elastic — spawned processes respawn from their pickled
payloads.  Services are different: they are parent-resident objects behind
courier ``Server``s (replay shards, the counter, learner replicas), so a
"death" cannot be a SIGKILL of some child pid.  The ``ServiceWatchdog``
simulates the same client-visible failure surface instead:

- **kill**: ``mark_down()`` the instance (in-parent callers see
  ``ServiceUnavailable`` on the data path) and stop its courier server
  (remote callers see connection-refused), then classify the synthetic
  exit code with ``classify_exit`` and charge the ``RestartPolicy`` budget
  exactly like a dead worker.
- **respawn**: after the policy's backoff, restore the last periodic
  snapshot via ``load_state_dict()`` (writes since the snapshot are lost —
  the realistic contract), ``mark_up()``, and re-bind a courier ``Server``
  at the SAME address with the SAME authkey, so every pickled
  ``RemoteHandle`` in the fleet reconnects without re-resolution.

Snapshots reuse the temp + fsync + ``os.replace`` discipline from
``run_checkpoint`` — a crash mid-write leaves the previous snapshot
intact.  Any object with ``state_dict()`` / ``load_state_dict()`` is
*recoverable*; ``mark_down()`` / ``mark_up()`` additionally make it a
valid chaos kill target.
"""
from __future__ import annotations

import os
import pickle
import sys
import tempfile
import threading
import time
from typing import Any, Dict, Optional

from repro.resilience.supervisor import RestartPolicy, classify_exit

# How often the watchdog snapshots each live recoverable service.
DEFAULT_SNAPSHOT_PERIOD_S = 0.5


def is_recoverable(instance: Any) -> bool:
    """True if the object carries restorable state: callable
    ``state_dict()`` and ``load_state_dict()``."""
    return (callable(getattr(instance, "state_dict", None))
            and callable(getattr(instance, "load_state_dict", None)))


def supports_down(instance: Any) -> bool:
    """True if the object can simulate death: callable ``mark_down()`` and
    ``mark_up()``."""
    return (callable(getattr(instance, "mark_down", None))
            and callable(getattr(instance, "mark_up", None)))


def service_activity(instance: Any) -> int:
    """A monotonic activity counter for chaos kill triggers.

    Services have no ``observe()`` to wrap (clients reach replay shards
    through direct in-memory refs, so a proxy would be bypassed), so kill
    schedules trigger on the service's own progress: services exposing an
    ``activity()`` counter (the async parameter service counts pushes +
    pulls) report it directly, replay tables count rate-limiter inserts +
    samples, learner replicas count steps taken, counters count their
    totals.
    """
    activity = getattr(instance, "activity", None)
    if callable(activity):
        return int(activity())
    limiter = getattr(instance, "rate_limiter", None)
    if limiter is not None:
        return int(limiter.inserts + limiter.samples)
    steps = getattr(instance, "steps_taken", None)
    if steps is not None:
        return int(steps)
    get_counts = getattr(instance, "get_counts", None)
    if callable(get_counts):
        return int(sum(get_counts().values()))
    return 0


def atomic_pickle(path: str, obj: Any):
    """Pickle ``obj`` to ``path`` crash-safely (temp + fsync + replace)."""
    directory = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=directory, prefix=".tmp-",
                               suffix=".pkl")
    try:
        with os.fdopen(fd, "wb") as f:
            pickle.dump(obj, f, protocol=pickle.HIGHEST_PROTOCOL)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class ServiceWatchdog:
    """Parent-side supervisor for ``role="service"`` nodes.

    Runs one daemon thread that (1) snapshots every registered recoverable
    service each ``snapshot_period_s``, (2) fires ``ServiceKillSchedule``s
    from the program's ``ChaosPolicy`` once a target's activity passes its
    kill step, and (3) performs due respawns.  Restart accounting mirrors
    the worker monitor: ``classify_exit`` on the synthetic exit code,
    ``RestartPolicy.should_restart`` against a per-service budget,
    exponential backoff between death and respawn, and a fail-fast
    ``_record_error`` on the owning launcher when the budget is exhausted.
    """

    def __init__(self, launcher, policy: RestartPolicy, chaos=None,
                 snapshot_period_s: float = DEFAULT_SNAPSHOT_PERIOD_S,
                 snapshot_dir: Optional[str] = None):
        if snapshot_period_s <= 0:
            raise ValueError(f"snapshot_period_s must be > 0, "
                             f"got {snapshot_period_s}")
        self._launcher = launcher
        self._policy = policy
        self._chaos = chaos
        self._period = snapshot_period_s
        self._dir = snapshot_dir or tempfile.mkdtemp(prefix="repro-failover-")
        self._services: Dict[str, Any] = {}
        self._schedules: Dict[str, Any] = {}
        self._rebind: Dict[str, tuple] = {}
        self._down: set = set()
        self._respawn_at: Dict[str, float] = {}
        self._restarts: Dict[str, int] = {}
        self._exit_kinds: Dict[str, list] = {}
        self._last_snapshot_at = 0.0
        self._snapshot_warned = False
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._m_restarts: Optional[tuple] = None

    # -- registration / lifecycle -------------------------------------

    def register(self, name: str, instance: Any):
        """Track a service node.  Recoverable instances are snapshotted;
        chaos kill targets must additionally support mark_down/mark_up."""
        if instance is None:
            return
        if is_recoverable(instance):
            self._services[name] = instance
        if self._chaos is not None:
            schedule = self._chaos.service_schedule_for(name)
            if schedule is not None:
                if not supports_down(instance):
                    raise ValueError(
                        f"chaos kill target {name!r} is a service without "
                        f"mark_down()/mark_up() — it cannot simulate death")
                self._schedules[name] = schedule

    def start(self) -> "ServiceWatchdog":
        self._thread = threading.Thread(
            target=self._run, name="launcher/service-watchdog", daemon=True)
        self._thread.start()
        return self

    def request_stop(self):
        """Signal the thread to exit (non-blocking; safe from any thread,
        including the watchdog's own error path)."""
        self._stop.set()

    def join(self, timeout: Optional[float] = None):
        self.request_stop()
        if self._thread is not None:
            self._thread.join(timeout)

    # -- stats ---------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "service_restarts": dict(self._restarts),
                "service_exit_kinds": {n: list(k)
                                       for n, k in self._exit_kinds.items()},
            }

    # -- the loop ------------------------------------------------------

    def _run(self):
        while not self._stop.wait(0.05):
            try:
                self._tick()
            except Exception as e:  # a watchdog bug must fail loudly
                self._launcher._record_error(RuntimeError(
                    f"service watchdog died: {type(e).__name__}: {e}"))
                return

    def _tick(self):
        if self._launcher.should_stop():
            self._stop.set()
            return
        now = time.monotonic()
        for name, schedule in list(self._schedules.items()):
            with self._lock:
                busy = name in self._down or name in self._respawn_at
            if busy or schedule.fired >= schedule.max_kills:
                continue
            if service_activity(self._get_instance(name)) >= schedule.kill_step:
                schedule.fired += 1
                self.kill(name, schedule.exit_code)
        with self._lock:
            due = [n for n, at in self._respawn_at.items() if now >= at]
        for name in due:
            with self._lock:
                self._respawn_at.pop(name, None)
            self._respawn(name)
        if now - self._last_snapshot_at >= self._period:
            self._last_snapshot_at = now
            self.snapshot_now()

    def _get_instance(self, name: str) -> Any:
        instance = self._services.get(name)
        if instance is None:
            node = self._launcher.program.node(name)
            instance = node.instance
        return instance

    def _snapshot_path(self, name: str) -> str:
        return os.path.join(self._dir, name.replace("/", "__") + ".pkl")

    def snapshot_now(self):
        """Snapshot every live recoverable service (also called on the
        periodic cadence; public so tests can force a deterministic cut)."""
        for name, instance in self._services.items():
            with self._lock:
                if name in self._down or name in self._respawn_at:
                    continue
            try:
                state = instance.state_dict()
                atomic_pickle(self._snapshot_path(name), state)
            except Exception as e:
                if not self._snapshot_warned:
                    self._snapshot_warned = True
                    print(f"[launcher] service snapshot of {name!r} failed "
                          f"({type(e).__name__}: {e}) — failover for it "
                          f"would restore an older snapshot",
                          file=sys.stderr, flush=True)

    # -- kill / respawn ------------------------------------------------

    def kill(self, name: str, exit_code: int = 1):
        """Simulate abrupt death of service ``name``: mark it down, tear
        down its courier server, and schedule a budgeted respawn."""
        instance = self._get_instance(name)
        if instance is None:
            raise ValueError(f"unknown service {name!r}")
        stopping = self._launcher.should_stop()
        with self._lock:
            if name in self._down:
                return
            self._down.add(name)
        if supports_down(instance):
            instance.mark_down()
        server = self._launcher._servers.get(name)
        if server is not None:
            with self._lock:
                self._rebind[name] = (server.address, server.authkey,
                                      server.interface)
            server.stop()
        if stopping:
            return  # teardown noise — no accounting, no respawn
        kind = classify_exit(exit_code, stopping=False)
        with self._lock:
            self._exit_kinds.setdefault(name, []).append(kind)
            count = self._restarts.get(name, 0)
            restart = self._policy.should_restart(kind, count)
            if restart:
                delay = self._policy.backoff(count)
                self._restarts[name] = count + 1
                self._respawn_at[name] = time.monotonic() + delay
        if restart:
            print(f"[launcher] service {name!r} died ({kind}, exit "
                  f"{exit_code}) — restoring from snapshot in {delay:.2f}s "
                  f"(restart {count + 1}/{self._policy.max_restarts})",
                  flush=True)
        else:
            self._launcher._record_error(RuntimeError(
                f"service {name!r} died ({kind}, exit {exit_code}) and is "
                f"not restartable under the policy "
                f"(restarts={count}/{self._policy.max_restarts})"))

    def _respawn(self, name: str):
        if self._launcher.should_stop():
            return
        instance = self._get_instance(name)
        path = self._snapshot_path(name)
        if is_recoverable(instance) and os.path.exists(path):
            try:
                with open(path, "rb") as f:
                    state = pickle.load(f)
                instance.load_state_dict(state)
            except Exception as e:
                self._launcher._record_error(RuntimeError(
                    f"restoring service {name!r} from its snapshot failed: "
                    f"{type(e).__name__}: {e}"))
                return
        # state restored BEFORE the service comes back up: clients must
        # never observe a half-restored instance.
        if supports_down(instance):
            instance.mark_up()
        with self._lock:
            rebind = self._rebind.pop(name, None)
        if rebind is not None:
            address, authkey, interface = rebind
            try:
                from repro.distributed.courier import Server
                server = Server(instance, interface=interface, name=name,
                                host=address[0], port=address[1],
                                authkey=authkey).start()
            except OSError:
                # the old port is still draining — retry shortly
                with self._lock:
                    self._rebind[name] = rebind
                    self._respawn_at[name] = time.monotonic() + 0.25
                return
            self._launcher._servers[name] = server
        with self._lock:
            self._down.discard(name)
        metrics = self._restarts_metric(name)
        if metrics:
            for m in metrics:
                m.inc()
        print(f"[launcher] service {name!r} restored and re-bound "
              f"at the same address", flush=True)

    def _restarts_metric(self, name: str):
        from repro.telemetry import registry as _telemetry
        if not _telemetry.enabled():
            return None
        return (_telemetry.counter("resilience/service_restarts"),
                _telemetry.counter(f"resilience/service_restarts/{name}"))
