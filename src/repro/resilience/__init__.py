"""Fault tolerance for distributed runs (§4.2 of the paper).

Four pillars:

- ``RunCheckpointer`` — a consistent, crash-safe snapshot of an entire run
  (learner pytree, replay contents, counter totals, RNG/cadence streams),
  so ``resume=True`` restarts bit-for-bit.
- ``RestartPolicy`` / ``classify_exit`` — the elastic-pool supervisor
  contract: worker deaths are classified (crash / preempted / shutdown)
  and ``role="worker"`` replicas respawn with exponential backoff under a
  max-restarts budget.
- ``ServiceWatchdog`` (``failover``) — the same elasticity for stateful
  ``role="service"`` nodes: periodic snapshots of every recoverable
  service, budgeted restore on a kill, and a courier re-bind at the same
  address so the fleet's pickled handles reconnect transparently.
- ``ChaosPolicy`` — seeded fault injection (kill-after-N-steps workers,
  activity-triggered service kills, RPC delay/drop at the courier layer)
  for acceptance-testing the above.
"""
from repro.resilience.chaos import (ChaosPolicy,  # noqa: F401
                                    KillSchedule, RPCChaosInjector,
                                    ServiceKillSchedule)
from repro.resilience.failover import (ServiceWatchdog,  # noqa: F401
                                       atomic_pickle, is_recoverable,
                                       service_activity, supports_down)
from repro.resilience.run_checkpoint import (RunCheckpointer,  # noqa: F401
                                             RunSnapshot)
from repro.resilience.supervisor import (CRASH, PREEMPTED,  # noqa: F401
                                         SHUTDOWN, RestartPolicy,
                                         classify_exit)
