"""Fault tolerance for distributed runs (§4.2 of the paper).

Three pillars:

- ``RunCheckpointer`` — a consistent, crash-safe snapshot of an entire run
  (learner pytree, replay contents, counter totals, RNG/cadence streams),
  so ``resume=True`` restarts bit-for-bit.
- ``RestartPolicy`` / ``classify_exit`` — the elastic-pool supervisor
  contract: worker deaths are classified (crash / preempted / shutdown)
  and ``role="worker"`` replicas respawn with exponential backoff under a
  max-restarts budget.
- ``ChaosPolicy`` — seeded fault injection (kill-after-N-steps workers,
  RPC delay/drop at the courier layer) for acceptance-testing the above.
"""
from repro.resilience.chaos import (ChaosPolicy,  # noqa: F401
                                    KillSchedule, RPCChaosInjector)
from repro.resilience.run_checkpoint import (RunCheckpointer,  # noqa: F401
                                             RunSnapshot)
from repro.resilience.supervisor import (CRASH, PREEMPTED,  # noqa: F401
                                         SHUTDOWN, RestartPolicy,
                                         classify_exit)
