"""Seeded chaos injection: worker kills and courier RPC faults.

A ``ChaosPolicy`` travels on ``ExperimentConfig`` and is resolved per
worker node at assembly time:

- ``schedule_for(node_name)`` yields a picklable ``KillSchedule`` for the
  targeted actor replicas.  The schedule wraps the worker's actor and
  hard-kills the process (``os._exit``) after N environment steps — the
  same failure surface as an OOM kill or a lost machine, which is exactly
  what the elastic supervisor must absorb.
- ``service_schedule_for(node_name)`` targets ``role="service"`` nodes
  (replay shards, learner replicas, the counter): the parent-side
  ``ServiceWatchdog`` polls the target's activity counter and simulates
  the death — mark_down + courier-server teardown — then restores it from
  its last snapshot under the same ``RestartPolicy`` budget.
- ``rpc_injector()`` yields an ``RPCChaosInjector`` installed at the
  courier layer inside the worker: per-call seeded delays and simulated
  connection drops, exercised *before* the request is sent so a dropped
  call is always safe to retry.

Respawned workers see ``REPRO_WORKER_RESTARTS`` (set by the launcher) and
disarm their kill schedule once ``max_kills`` deaths have been delivered —
otherwise a chaos target would kill itself fresh after every respawn and
burn the whole restart budget.
"""
from __future__ import annotations

import dataclasses
import os
import random
import sys
import threading
import time
from typing import Optional, Tuple

# Set by MultiprocessLauncher._child_main: how many times this worker has
# already been respawned (0 for the first launch).
RESTARTS_ENV = "REPRO_WORKER_RESTARTS"


def worker_restarts() -> int:
    try:
        return int(os.environ.get(RESTARTS_ENV, "0"))
    except ValueError:
        return 0


class KillSchedule:
    """Kill this process after ``kill_step`` actor steps (picklable)."""

    def __init__(self, node: str, kill_step: int, exit_code: int,
                 max_kills: int):
        if kill_step < 1:
            raise ValueError("kill_step must be >= 1")
        self.node = node
        self.kill_step = int(kill_step)
        self.exit_code = int(exit_code)
        self.max_kills = int(max_kills)
        self._count = 0

    @property
    def armed(self) -> bool:
        return worker_restarts() < self.max_kills

    def wrap(self, actor):
        if not self.armed:
            return actor
        return _ChaosActor(actor, self)

    def tick(self):
        self._count += 1
        if self._count >= self.kill_step:
            print(f"[chaos] {self.node}: killing worker after "
                  f"{self._count} steps (exit {self.exit_code})",
                  file=sys.stderr, flush=True)
            # A real kill, not an exception: no cleanup, no error-queue
            # report — the supervisor must notice the silent death.
            os._exit(self.exit_code)


class ServiceKillSchedule:
    """Kill a parent-resident service once its activity passes a threshold.

    Services have no process of their own and no ``observe()`` hook to
    wrap, so the trigger is the service's OWN progress counter
    (``repro.resilience.failover.service_activity``: replay inserts +
    samples, learner-replica steps, counter totals) polled by the
    ``ServiceWatchdog``, which then simulates the death (mark_down +
    courier-server teardown) and the budgeted restore.
    """

    def __init__(self, node: str, kill_step: int, exit_code: int,
                 max_kills: int):
        if kill_step < 1:
            raise ValueError("kill_step must be >= 1")
        self.node = node
        self.kill_step = int(kill_step)
        self.exit_code = int(exit_code)
        self.max_kills = int(max_kills)
        self.fired = 0  # kills delivered (the watchdog's disarm counter)


class _ChaosActor:
    """Actor wrapper counting environment steps via ``observe`` calls."""

    def __init__(self, actor, schedule: KillSchedule):
        self._actor = actor
        self._schedule = schedule

    def observe(self, *args, **kwargs):
        result = self._actor.observe(*args, **kwargs)
        self._schedule.tick()
        return result

    def __getattr__(self, name):
        return getattr(self._actor, name)


class RPCChaosInjector:
    """Courier-layer fault injection, consulted client-side before send."""

    def __init__(self, delay_ms: float = 0.0, drop_rate: float = 0.0,
                 seed: int = 0):
        self.delay_ms = float(delay_ms)
        self.drop_rate = float(drop_rate)
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self.injected = {"delays": 0, "drops": 0}

    def before_send(self):
        """Sleep (delay) and/or raise ``ConnectionError`` (drop).  Runs
        before any bytes hit the socket, so retrying is always safe."""
        with self._lock:
            delay = self.delay_ms if self.delay_ms > 0 else 0.0
            drop = (self.drop_rate > 0
                    and self._rng.random() < self.drop_rate)
            if delay:
                self.injected["delays"] += 1
            if drop:
                self.injected["drops"] += 1
        if delay:
            time.sleep(delay / 1000.0)
        if drop:
            raise ConnectionError("chaos: injected RPC drop")

    def install(self):
        from repro.distributed import courier
        courier.set_rpc_chaos(self)


@dataclasses.dataclass(frozen=True)
class ChaosPolicy:
    """Declarative, seeded fault schedule for a run.

    ``kill_targets`` name program nodes (e.g. ``("actor/0",)``); each gets
    a kill after ``kill_after_steps`` actor steps, plus a deterministic
    per-node jitter of up to ``kill_jitter_steps`` drawn from ``seed``.
    ``max_kills`` bounds deaths per target across respawns.  RPC faults
    apply to every courier client in the targeted workers.
    """

    kill_after_steps: Optional[int] = None
    kill_targets: Tuple[str, ...] = ()
    kill_jitter_steps: int = 0
    kill_exit_code: int = 42          # positive → classified as a crash
    max_kills: int = 1
    rpc_delay_ms: float = 0.0
    rpc_drop_rate: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.kill_after_steps is not None and self.kill_after_steps < 1:
            raise ValueError("kill_after_steps must be >= 1")
        if not 0.0 <= self.rpc_drop_rate < 1.0:
            raise ValueError("rpc_drop_rate must be in [0, 1)")
        if self.rpc_delay_ms < 0:
            raise ValueError("rpc_delay_ms must be >= 0")
        if self.kill_exit_code <= 0:
            raise ValueError("kill_exit_code must be > 0 (a crash)")

    def schedule_for(self, node: str) -> Optional[KillSchedule]:
        if self.kill_after_steps is None or node not in self.kill_targets:
            return None
        jitter = 0
        if self.kill_jitter_steps > 0:
            # str seeding hashes via sha512 — stable across processes,
            # unlike tuple hashing (PYTHONHASHSEED-randomized)
            rng = random.Random(f"{self.seed}/{node}")
            jitter = rng.randint(0, self.kill_jitter_steps)
        return KillSchedule(node, self.kill_after_steps + jitter,
                            self.kill_exit_code, self.max_kills)

    def service_schedule_for(self, node: str) -> Optional[ServiceKillSchedule]:
        """Like ``schedule_for`` but for ``role="service"`` nodes — same
        targeting, jitter, and budget; different delivery (watchdog-polled
        activity instead of a wrapped actor)."""
        if self.kill_after_steps is None or node not in self.kill_targets:
            return None
        jitter = 0
        if self.kill_jitter_steps > 0:
            rng = random.Random(f"{self.seed}/{node}")
            jitter = rng.randint(0, self.kill_jitter_steps)
        return ServiceKillSchedule(node, self.kill_after_steps + jitter,
                                   self.kill_exit_code, self.max_kills)

    def rpc_injector(self) -> Optional[RPCChaosInjector]:
        if self.rpc_delay_ms <= 0 and self.rpc_drop_rate <= 0:
            return None
        return RPCChaosInjector(self.rpc_delay_ms, self.rpc_drop_rate,
                                self.seed)
