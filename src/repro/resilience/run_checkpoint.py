"""Run-wide exact-resume checkpointing.

``RunCheckpointer`` coordinates one consistent snapshot of everything a
run needs to restart bit-for-bit:

- the learner pytree (via the existing npz ``Checkpointer``, under the
  ``learner`` name — merged state when a ``MultiLearner`` is in play);
- replay *contents* — ``Table.state_dict()`` / ``ShardedReplay
  .state_dict()``: items, priorities, selector internals (sum-tree array
  verbatim, RNG streams), rate-limiter accounting, routing cursors;
- counter totals and run bookkeeping (RNG/cadence counters, loop
  position), passed as opaque picklable dicts.

Write protocol (crash-safe at every boundary):

1. each component is written to a temp file, fsynced, and ``os.replace``d
   into ``learner_<step>.npz`` / ``replay_<step>.pkl`` /
   ``runstate_<step>.pkl``;
2. only then is the ``run_latest.json`` manifest atomically replaced and
   the directory fsynced — the manifest is the unit of atomicity: a crash
   anywhere earlier leaves the previous manifest (and its files, which gc
   never touches) fully intact;
3. garbage collection of steps older than ``keep`` runs last.

``restore`` reads the manifest, verifies every listed file exists
(``CheckpointError`` otherwise), and returns a ``RunSnapshot``.
"""
from __future__ import annotations

import json
import os
import pickle
import tempfile
import time
from typing import Any, Dict, NamedTuple, Optional

from repro.checkpoint import Checkpointer, CheckpointError, fsync_directory
from repro.telemetry import registry as _telemetry

MANIFEST = "run_latest.json"


class RunSnapshot(NamedTuple):
    step: int
    learner_state: Any
    replay: Optional[Dict]        # Table/ShardedReplay state_dict, or None
    counts: Optional[Dict]        # Counter totals
    run_state: Optional[Dict]     # RNG streams, cadence counters, loop pos.
    meta: Dict


class RunCheckpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._learner = Checkpointer(directory, name="learner", keep=keep)
        self._m_write = None
        self._m_restore = None

    def _metrics(self):
        if self._m_write is None:
            self._m_write = _telemetry.histogram(
                "resilience/checkpoint_write_ms")
            self._m_restore = _telemetry.histogram(
                "resilience/checkpoint_restore_ms")
        return self._m_write, self._m_restore

    # ------------------------------------------------------------ paths
    def _manifest_path(self) -> str:
        return os.path.join(self.directory, MANIFEST)

    def _component_path(self, component: str, step: int) -> str:
        return os.path.join(self.directory, f"{component}_{step}.pkl")

    def _write_pickle(self, path: str, payload: Any):
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".pkl.tmp")
        with os.fdopen(fd, "wb") as f:
            pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    # ------------------------------------------------------------- save
    def save(self, step: int, learner_state: Any, *,
             replay: Optional[Dict] = None,
             counts: Optional[Dict] = None,
             run_state: Optional[Dict] = None,
             meta: Optional[Dict] = None):
        m_write, _ = self._metrics()
        t0 = time.monotonic()
        step = int(step)
        files = {"learner": f"learner_{step}.npz"}
        self._learner.save(learner_state, step)
        if replay is not None:
            path = self._component_path("replay", step)
            self._write_pickle(path, replay)
            files["replay"] = os.path.basename(path)
        runstate_path = self._component_path("runstate", step)
        self._write_pickle(runstate_path, {"counts": counts,
                                           "run_state": run_state})
        files["runstate"] = os.path.basename(runstate_path)
        # Manifest last: everything it references is already durable.
        manifest = {"step": step, "files": files, "meta": dict(meta or {})}
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".json.tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._manifest_path())
        fsync_directory(self.directory)
        self._gc(step)
        if m_write:
            m_write.observe((time.monotonic() - t0) * 1000.0)

    def _gc(self, latest: int):
        steps = self.list_steps()
        keep = set(steps[-self.keep:]) | {latest}
        for step in steps:
            if step in keep:
                continue
            for component in ("replay", "runstate"):
                path = self._component_path(component, step)
                if os.path.exists(path):
                    os.unlink(path)

    def list_steps(self):
        steps = set()
        for f in os.listdir(self.directory):
            if f.startswith("runstate_") and f.endswith(".pkl"):
                try:
                    steps.add(int(f[len("runstate_"):-4]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        manifest = self._read_manifest()
        return None if manifest is None else int(manifest["step"])

    def _read_manifest(self) -> Optional[Dict]:
        try:
            with open(self._manifest_path()) as f:
                return json.load(f)
        except OSError:
            return None
        except ValueError as e:
            raise CheckpointError(
                f"corrupt run manifest {self._manifest_path()}: {e}")

    # ---------------------------------------------------------- restore
    def restore(self, learner_template: Any) -> Optional[RunSnapshot]:
        """Restore the manifest's snapshot, or None when nothing saved."""
        manifest = self._read_manifest()
        if manifest is None:
            return None
        _, m_restore = self._metrics()
        t0 = time.monotonic()
        step = int(manifest["step"])
        files = manifest.get("files", {})
        for component, name in files.items():
            path = os.path.join(self.directory, name)
            if not os.path.exists(path):
                raise CheckpointError(
                    f"run manifest points at step {step} but {component} "
                    f"file {name} is missing")
        learner_state, _ = self._learner.restore(learner_template, step)
        replay = None
        if "replay" in files:
            with open(os.path.join(self.directory, files["replay"]),
                      "rb") as f:
                replay = pickle.load(f)
        with open(os.path.join(self.directory, files["runstate"]),
                  "rb") as f:
            runstate = pickle.load(f)
        snapshot = RunSnapshot(step=step, learner_state=learner_state,
                               replay=replay,
                               counts=runstate.get("counts"),
                               run_state=runstate.get("run_state"),
                               meta=manifest.get("meta", {}))
        if m_restore:
            m_restore.observe((time.monotonic() - t0) * 1000.0)
        return snapshot
