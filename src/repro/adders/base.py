"""Adders (§2.3): the insertion-side pre-processing between actor and table."""
from __future__ import annotations

import abc
from typing import Any, Optional

from repro.core.types import TimeStep


class Adder(abc.ABC):
    @abc.abstractmethod
    def add_first(self, timestep: TimeStep):
        ...

    @abc.abstractmethod
    def add(self, action, next_timestep: TimeStep, extras: Any = ()):
        ...

    def reset(self):
        pass
