"""Adders (§2.3): the insertion-side pre-processing between actor and table."""
from __future__ import annotations

import abc
from typing import Any, Optional

from repro.core.types import TimeStep


class Adder(abc.ABC):
    # Subclasses whose add_first accepts a second ``extras`` argument
    # (recurrent core state at sequence starts) declare
    # ``supports_extras = True``; ``supports_extras = False`` explicitly
    # opts out.  Deliberately NOT defaulted here: an inherited default would
    # shadow the ``inspect.signature`` arity fallback in
    # ``repro.core.actors.adder_takes_extras`` for adders that predate the
    # flag.  Actors must use that helper — never probe by calling add_first
    # inside try/except TypeError, which masks real TypeErrors raised in
    # the adder.

    @abc.abstractmethod
    def add_first(self, timestep: TimeStep):
        ...

    @abc.abstractmethod
    def add(self, action, next_timestep: TimeStep, extras: Any = ()):
        ...

    def reset(self):
        pass
