from repro.adders.base import Adder  # noqa: F401
from repro.adders.sequence import EpisodeAdder, SequenceAdder  # noqa: F401
from repro.adders.transition import NStepTransitionAdder, TransitionAdder  # noqa: F401
