"""Sequence and episode adders (R2D2/IMPALA-family, §3.2).

``SequenceAdder`` writes fixed-length sequences with configurable stride
(overlapping when stride < length, R2D2-style with burn-in prefix included in
the stored sequence; strided/non-overlapping for IMPALA queues).  Recurrent
core state at the start of each stored sequence can be attached via
``extras`` so learners can reconstruct state ("stale state" + burn-in, as the
paper describes).

``EpisodeAdder`` writes whole episodes (MCTS / demonstration ingestion).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from repro.adders.base import Adder
from repro.core.types import TimeStep
from repro.replay.table import Table


def _seq_item(steps: List[Dict[str, Any]], pad_to: Optional[int] = None):
    """Stack a list of per-step dicts into arrays; zero-pad to pad_to."""
    import jax
    out = jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]), *steps)
    if pad_to is not None and len(steps) < pad_to:
        pad = pad_to - len(steps)
        out = jax.tree.map(
            lambda x: np.concatenate(
                [x, np.zeros((pad,) + x.shape[1:], x.dtype)], axis=0), out)
    mask = np.zeros(pad_to or len(steps), np.float32)
    mask[:len(steps)] = 1.0
    out["mask"] = mask
    return out


class SequenceAdder(Adder):
    supports_extras = True   # add_first(timestep, extras): recurrent state

    def __init__(self, table: Table, sequence_length: int, period: int,
                 priority: float = 1.0, pad_end: bool = True):
        if period <= 0 or sequence_length <= 0:
            raise ValueError("period and sequence_length must be positive")
        self.table = table
        self.length = sequence_length
        self.period = period
        self.default_priority = priority
        self.pad_end = pad_end
        self._steps: List[Dict[str, Any]] = []
        self._since_write = 0
        self._obs = None
        self._start_extras = None

    def reset(self):
        self._steps = []
        self._since_write = 0
        self._obs = None
        self._start_extras = None

    def add_first(self, timestep: TimeStep, extras: Any = ()):
        self.reset()
        self._obs = timestep.observation
        self._start_extras = extras

    def add(self, action, next_timestep: TimeStep, extras: Any = ()):
        if self._obs is None:
            raise RuntimeError("add() before add_first()")
        step = {
            "observation": np.asarray(self._obs),
            "action": np.asarray(action),
            "reward": np.float32(next_timestep.reward),
            "discount": np.float32(next_timestep.discount),
            "start_of_episode": np.bool_(len(self._steps) == 0),
        }
        if extras:
            step.update({k: np.asarray(v) for k, v in dict(extras).items()})
        self._steps.append(step)
        self._obs = next_timestep.observation
        self._since_write += 1

        if len(self._steps) == self.length:
            self._write()
            # keep overlap: drop `period` steps from the front
            self._steps = self._steps[self.period:]
            self._since_write = 0
        if next_timestep.last():
            if self._steps and self.pad_end:
                self._write(pad=True)
            self.reset()

    def _write(self, pad: bool = False):
        item = _seq_item(self._steps, pad_to=self.length if pad else None)
        self.table.insert(item, priority=self.default_priority)


class EpisodeAdder(Adder):
    def __init__(self, table: Table, max_episode_length: int = 10_000,
                 priority: float = 1.0):
        self.table = table
        self.max_len = max_episode_length
        self.default_priority = priority
        self._steps: List[Dict[str, Any]] = []
        self._obs = None

    def reset(self):
        self._steps = []
        self._obs = None

    def add_first(self, timestep: TimeStep):
        self.reset()
        self._obs = timestep.observation

    def add(self, action, next_timestep: TimeStep, extras: Any = ()):
        if self._obs is None:
            raise RuntimeError("add() before add_first()")
        self._steps.append({
            "observation": np.asarray(self._obs),
            "action": np.asarray(action),
            "reward": np.float32(next_timestep.reward),
            "discount": np.float32(next_timestep.discount),
        })
        self._obs = next_timestep.observation
        if len(self._steps) >= self.max_len or next_timestep.last():
            self.table.insert(_seq_item(self._steps),
                              priority=self.default_priority)
            self.reset()
            if not next_timestep.last():
                self._obs = next_timestep.observation
