"""Transition and n-step-transition adders (DQN/DDPG-family, §3.2).

``NStepTransitionAdder`` stores overlapping n-step transitions
(o_t, a_t, sum_i gamma^i r_{t+i}, prod discounts, o_{t+n}) — "functionally
equivalent to single-step transitions and using the same storage" as the
paper notes.  Priorities default to max-priority-on-insert so prioritized
tables sample fresh data first.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Optional

import numpy as np

from repro.adders.base import Adder
from repro.core.types import TimeStep, Transition
from repro.replay.table import Table


class NStepTransitionAdder(Adder):
    def __init__(self, table: Table, n_step: int = 1, discount: float = 0.99,
                 priority: float = 1.0):
        self.table = table
        self.n = int(n_step)
        self.gamma = float(discount)
        self.default_priority = priority
        self._buffer: deque = deque()
        self._obs = None

    def reset(self):
        self._buffer.clear()
        self._obs = None

    def add_first(self, timestep: TimeStep):
        self.reset()
        self._obs = timestep.observation

    def add(self, action, next_timestep: TimeStep, extras: Any = ()):
        if self._obs is None:
            raise RuntimeError("add() before add_first()")
        self._buffer.append(
            (self._obs, action, float(next_timestep.reward),
             float(next_timestep.discount), extras))
        self._obs = next_timestep.observation

        if len(self._buffer) == self.n:
            self._write(next_timestep.observation)
            self._buffer.popleft()
        if next_timestep.last():
            # flush the remaining (shorter) transitions at episode end
            while self._buffer:
                self._write(next_timestep.observation)
                self._buffer.popleft()
            self._obs = None

    def _write(self, next_obs):
        obs, action, _, _, extras = self._buffer[0]
        r, g = 0.0, 1.0
        for (_, _, rew, disc, _) in self._buffer:
            r += g * rew
            g *= self.gamma * disc
        item = Transition(np.asarray(obs), np.asarray(action),
                          np.float32(r), np.float32(g),
                          np.asarray(next_obs), extras)
        self.table.insert(item, priority=self.default_priority)


class TransitionAdder(NStepTransitionAdder):
    def __init__(self, table: Table, discount: float = 0.99, priority: float = 1.0):
        super().__init__(table, n_step=1, discount=discount, priority=priority)
