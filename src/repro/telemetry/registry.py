"""Per-process metric registry: counters, gauges, bounded-reservoir
histograms, and snapshot-time probes.

The §4.2 loggers record *rows a component chose to emit*; the registry
records *what the hot paths actually did* — call latencies, queue waits,
batch occupancies, block times — cheaply enough to leave on in production
runs and at literally-zero cost when off:

- When a registry is DISABLED, ``counter()``/``gauge()``/``histogram()``
  return a shared null metric whose mutators are no-ops and whose truth
  value is ``False`` — hot paths guard their ``time.monotonic()`` calls
  with ``if self._m_latency:`` so a disabled run pays one truthiness check
  per event and nothing else.
- When ENABLED, every metric is individually locked (no registry-wide
  bottleneck on the sample path) and ``snapshot()`` returns plain-python
  summaries that pickle across courier and dump to JSON unchanged.

Metric naming convention: ``component/detail/metric`` (e.g.
``courier/client/replay/insert/latency_ms``); the NODE prefix of the
run-wide ``node/component/metric`` convention is added by the
``MetricsHub``, which keys pushed snapshots by the pushing node's name.

Histograms keep a bounded reservoir (Vitter's algorithm R): a uniform
sample of everything observed, so quantiles stay honest at any event count
with O(1) memory.  Snapshots carry the reservoir so the hub can merge
cross-node quantiles instead of averaging percentiles (which is wrong).

Probes cover state that has no event to hook: ``probe(prefix, fn)``
registers a callable returning ``{suffix: value}`` that is evaluated at
``snapshot()`` time and exported as gauges named ``prefix/suffix`` —
replay occupancy, cache-slot utilization, averaging rounds.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

DEFAULT_RESERVOIR = 512
QUANTILES = (0.5, 0.95, 0.99)


def quantile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation quantile of an already-SORTED sequence
    (numpy's default method, without the numpy dependency)."""
    if not values:
        return float("nan")
    if len(values) == 1:
        return float(values[0])
    pos = q * (len(values) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(values) - 1)
    frac = pos - lo
    return float(values[lo] * (1.0 - frac) + values[hi] * frac)


class NullMetric:
    """Shared do-nothing stand-in returned by a disabled registry.

    Falsy on purpose: hot paths write ``t0 = time.monotonic() if
    self._metric else 0.0`` so a disabled run never even reads the clock.
    """

    __slots__ = ()

    def __bool__(self) -> bool:
        return False

    def inc(self, n: int = 1):
        pass

    def set(self, value: float):
        pass

    def observe(self, value: float):
        pass


NULL_METRIC = NullMetric()


class Counter:
    """Monotonic event count (merge rule across nodes: SUM)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1):
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written level (merge rule across nodes: mean/min/max)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float):
        with self._lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Bounded-reservoir distribution with p50/p95/p99 summaries.

    Reservoir sampling (algorithm R) keeps a uniform sample of ALL
    observations in ``max_samples`` slots; count/sum/min/max are exact.
    The RNG is seeded from the metric name so runs are reproducible.
    """

    __slots__ = ("name", "max_samples", "_lock", "_rng", "_reservoir",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, name: str, max_samples: int = DEFAULT_RESERVOIR):
        if max_samples < 1:
            raise ValueError(f"max_samples must be >= 1, got {max_samples}")
        self.name = name
        self.max_samples = int(max_samples)
        self._lock = threading.Lock()
        self._rng = random.Random(hash(name) & 0xFFFFFFFF)
        self._reservoir: List[float] = []
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")

    def observe(self, value: float):
        value = float(value)
        with self._lock:
            self._count += 1
            self._sum += value
            if value < self._min:
                self._min = value
            if value > self._max:
                self._max = value
            if len(self._reservoir) < self.max_samples:
                self._reservoir.append(value)
            else:
                j = self._rng.randrange(self._count)
                if j < self.max_samples:
                    self._reservoir[j] = value

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            count, total = self._count, self._sum
            lo, hi = self._min, self._max
            reservoir = list(self._reservoir)
        if count == 0:
            return {"type": "histogram", "count": 0}
        reservoir.sort()
        summary = {
            "type": "histogram",
            "count": count,
            "sum": total,
            "mean": total / count,
            "min": lo,
            "max": hi,
            "reservoir": reservoir,
        }
        for q in QUANTILES:
            summary[f"p{int(q * 100)}"] = quantile(reservoir, q)
        return summary


class _TimerContext:
    __slots__ = ("_histogram", "_t0")

    def __init__(self, histogram: Histogram):
        self._histogram = histogram

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self._histogram.observe((time.monotonic() - self._t0) * 1000.0)
        return False


class _NullTimer:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_TIMER = _NullTimer()


def timer(histogram):
    """``with timer(hist):`` — observe the block's duration in ms; a null
    (falsy) histogram yields a no-op context that never reads the clock."""
    return _TimerContext(histogram) if histogram else _NULL_TIMER


class MetricRegistry:
    """One process's (or node's) metrics, keyed by name.

    ``counter``/``gauge``/``histogram`` create-or-return the named metric;
    asking for an existing name with a different type is an error (two
    components silently sharing one metric is a bug, not a merge).
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}
        self._probes: Dict[str, Callable[[], Mapping[str, float]]] = {}

    # ------------------------------------------------------------- creation
    def _get_or_create(self, name: str, cls, *args):
        if not self.enabled:
            return NULL_METRIC
        with self._lock:
            metric = self._metrics.get(name)
            if metric is None:
                metric = cls(name, *args)
                self._metrics[name] = metric
            elif not isinstance(metric, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(metric).__name__}, not {cls.__name__}")
            return metric

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str,
                  max_samples: int = DEFAULT_RESERVOIR) -> Histogram:
        return self._get_or_create(name, Histogram, max_samples)

    def probe(self, prefix: str, fn: Callable[[], Mapping[str, float]]):
        """Register ``fn`` to be evaluated at snapshot time; its
        ``{suffix: value}`` result is exported as gauges named
        ``prefix/suffix``.  A colliding prefix is auto-suffixed ``#2``,
        ``#3``, … (several engines/pools may coexist in one process)."""
        if not self.enabled:
            return
        with self._lock:
            key = prefix
            n = 2
            while key in self._probes:
                key = f"{prefix}#{n}"
                n += 1
            self._probes[key] = fn

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Plain-python summary of every metric and probe — picklable over
        courier and JSON-serializable once reservoirs are stripped."""
        with self._lock:
            metrics = dict(self._metrics)
            probes = dict(self._probes)
        out: Dict[str, Dict[str, Any]] = {}
        for name, metric in metrics.items():
            out[name] = metric.snapshot()
        for prefix, fn in probes.items():
            try:
                values = fn()
            except Exception:   # a dying component must not break telemetry
                continue
            for suffix, value in values.items():
                try:
                    out[f"{prefix}/{suffix}"] = {"type": "gauge",
                                                 "value": float(value)}
                except (TypeError, ValueError):
                    continue   # non-numeric probe outputs are skipped
        return out

    def reset(self):
        with self._lock:
            self._metrics.clear()
            self._probes.clear()


def merge_snapshots(
        node_snapshots: Mapping[str, Mapping[str, Mapping[str, Any]]],
) -> Dict[str, Dict[str, Any]]:
    """Merge per-node snapshots into one run-wide view, keyed by metric
    name.  Counters SUM; gauges report mean/min/max across nodes;
    histograms combine exact count/sum/min/max and recompute quantiles
    from the concatenated reservoirs (averaging percentiles would be
    statistically wrong).  Every merged entry carries ``nodes`` — how many
    nodes contributed."""
    by_name: Dict[str, List[Mapping[str, Any]]] = {}
    for snapshot in node_snapshots.values():
        for name, summary in snapshot.items():
            by_name.setdefault(name, []).append(summary)

    merged: Dict[str, Dict[str, Any]] = {}
    for name, summaries in by_name.items():
        kind = summaries[0].get("type")
        if any(s.get("type") != kind for s in summaries):
            continue   # same name, different types across nodes: skip
        if kind == "counter":
            merged[name] = {"type": "counter",
                            "value": sum(s["value"] for s in summaries),
                            "nodes": len(summaries)}
        elif kind == "gauge":
            values = [s["value"] for s in summaries]
            merged[name] = {"type": "gauge",
                            "mean": sum(values) / len(values),
                            "min": min(values), "max": max(values),
                            "nodes": len(summaries)}
        elif kind == "histogram":
            live = [s for s in summaries if s.get("count", 0) > 0]
            if not live:
                merged[name] = {"type": "histogram", "count": 0,
                                "nodes": len(summaries)}
                continue
            count = sum(s["count"] for s in live)
            total = sum(s["sum"] for s in live)
            reservoir: List[float] = []
            for s in live:
                reservoir.extend(s.get("reservoir", ()))
            reservoir.sort()
            entry = {"type": "histogram", "count": count, "sum": total,
                     "mean": total / count,
                     "min": min(s["min"] for s in live),
                     "max": max(s["max"] for s in live),
                     "nodes": len(summaries)}
            for q in QUANTILES:
                entry[f"p{int(q * 100)}"] = quantile(reservoir, q)
            merged[name] = entry
    return merged


def strip_reservoirs(
        snapshot: Mapping[str, Mapping[str, Any]]) -> Dict[str, Dict]:
    """Summary-only copy of a snapshot (for JSONL export / extras views)."""
    out = {}
    for name, summary in snapshot.items():
        out[name] = {k: v for k, v in summary.items() if k != "reservoir"}
    return out


# ---------------------------------------------------------------------------
# Process-global registry.
#
# Instrumented components (courier, batching server, replay tables, …) pull
# their metrics from here so instrumentation needs no plumbing: the run
# entrypoint calls ``configure(...)`` once per process and every component
# constructed afterwards picks it up.  Until then the default registry is
# DISABLED and unconfigured — importing repro costs nothing, and
# ``WorkerTelemetry.install()`` uses ``is_configured()`` to tell a fresh
# spawn child (configure + start pusher) from a local-launcher worker
# sharing an already-configured parent (no-op).
# ---------------------------------------------------------------------------

_GLOBAL_LOCK = threading.Lock()
_GLOBAL_REGISTRY = MetricRegistry(enabled=False)
_GLOBAL_NODE = "unconfigured"
_GLOBAL_CONFIGURED = False


def configure(enabled: bool = True, node: str = "local") -> MetricRegistry:
    """(Re)configure this process's registry — called once per process by
    the run entrypoint (or ``WorkerTelemetry.install()`` in spawn
    children).  Always starts from a FRESH registry so metrics from a
    previous run in the same process can't leak into this one."""
    global _GLOBAL_REGISTRY, _GLOBAL_NODE, _GLOBAL_CONFIGURED
    with _GLOBAL_LOCK:
        _GLOBAL_REGISTRY = MetricRegistry(enabled=enabled)
        _GLOBAL_NODE = node
        _GLOBAL_CONFIGURED = True
        return _GLOBAL_REGISTRY


def unconfigure():
    """Reset to the import-time state (disabled, unconfigured) — used by
    run teardown so back-to-back runs in one process each reconfigure."""
    global _GLOBAL_REGISTRY, _GLOBAL_NODE, _GLOBAL_CONFIGURED
    with _GLOBAL_LOCK:
        _GLOBAL_REGISTRY = MetricRegistry(enabled=False)
        _GLOBAL_NODE = "unconfigured"
        _GLOBAL_CONFIGURED = False


def get_registry() -> MetricRegistry:
    return _GLOBAL_REGISTRY


def enabled() -> bool:
    return _GLOBAL_REGISTRY.enabled


def is_configured() -> bool:
    return _GLOBAL_CONFIGURED


def node_name() -> str:
    return _GLOBAL_NODE


def counter(name: str) -> Counter:
    return _GLOBAL_REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return _GLOBAL_REGISTRY.gauge(name)


def histogram(name: str, max_samples: int = DEFAULT_RESERVOIR) -> Histogram:
    return _GLOBAL_REGISTRY.histogram(name, max_samples)


def probe(prefix: str, fn: Callable[[], Mapping[str, float]]):
    return _GLOBAL_REGISTRY.probe(prefix, fn)


def snapshot() -> Dict[str, Dict[str, Any]]:
    return _GLOBAL_REGISTRY.snapshot()
