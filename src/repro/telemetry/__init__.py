"""repro.telemetry — run-wide observability for the distributed runtime.

Two layers:

- ``registry``: a per-process ``MetricRegistry`` (counters, gauges,
  bounded-reservoir histograms, snapshot-time probes) plus the
  process-global instance instrumented components record into.  Near-zero
  cost when disabled: metric getters return falsy null objects so hot
  paths skip even their ``time.monotonic()`` calls.
- ``hub``: a courier-addressable ``MetricsHub`` service node every worker
  pushes periodic snapshots to (keyed by node name), with merged run-wide
  views, JSONL export, and an end-of-run text report.

Enable via ``ExperimentConfig(telemetry=True)`` /
``BuilderOptions(telemetry=True)``; the merged snapshot lands in
``ExperimentResult.extras["telemetry"]``.  See ROADMAP "Distributed
telemetry" for the naming convention and how new services register.
"""

from repro.telemetry.registry import (
    DEFAULT_RESERVOIR,
    QUANTILES,
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    NULL_METRIC,
    NullMetric,
    configure,
    counter,
    enabled,
    gauge,
    get_registry,
    histogram,
    is_configured,
    merge_snapshots,
    node_name,
    probe,
    quantile,
    snapshot,
    strip_reservoirs,
    timer,
    unconfigure,
)
from repro.telemetry.hub import (
    HUB_INTERFACE,
    MetricsHub,
    MetricsPusher,
    WorkerTelemetry,
    format_report,
)

__all__ = [
    "DEFAULT_RESERVOIR",
    "QUANTILES",
    "Counter",
    "Gauge",
    "Histogram",
    "HUB_INTERFACE",
    "MetricRegistry",
    "MetricsHub",
    "MetricsPusher",
    "NULL_METRIC",
    "NullMetric",
    "WorkerTelemetry",
    "configure",
    "counter",
    "enabled",
    "format_report",
    "gauge",
    "get_registry",
    "histogram",
    "is_configured",
    "merge_snapshots",
    "node_name",
    "probe",
    "quantile",
    "snapshot",
    "strip_reservoirs",
    "timer",
    "unconfigure",
]
