"""Run-wide metrics aggregation: the ``MetricsHub`` service node and the
``MetricsPusher`` that feeds it.

Push model, not pull: every worker process runs one daemon
``MetricsPusher`` thread that periodically snapshots its process-local
``MetricRegistry`` and calls ``hub.push(node, snapshot)`` — over courier
when the hub lives in another process (multiprocess launcher), or as a
plain method call when everything shares the parent (local launcher).
Pull would require the hub to hold a handle to every worker; push means a
new service registers itself just by pushing, and a crashed worker's last
snapshot survives in the hub.

The hub keeps the LATEST snapshot per node (metrics are cumulative, so
the latest supersedes earlier pushes), merges them on demand via
``merge_snapshots``, optionally appends every push to a JSONL file
(reservoirs stripped — summaries only), and renders an end-of-run text
report.  ``HUB_INTERFACE`` is the courier RPC allowlist for the service
node.

``WorkerTelemetry`` is the picklable bootstrap that rides into spawn
children as a worker kwarg: calling ``install()`` configures the child's
process-global registry and starts its pusher — unless the process is
already configured (local launcher: all "workers" share the parent, whose
single pusher covers them), in which case it is a no-op.
"""
from __future__ import annotations

import json
import sys
import threading
import time
from typing import Any, Dict, Mapping, Optional

from repro.telemetry import registry as _registry
from repro.telemetry.registry import (QUANTILES, merge_snapshots,
                                      strip_reservoirs)

# Courier RPC allowlist for the hub's Program service node.
HUB_INTERFACE = ("push", "snapshot", "nodes", "report", "num_pushes")


class MetricsHub:
    """Aggregates per-node metric snapshots into one run-wide view.

    Thread-safe: courier serves each connection on its own thread, so
    concurrent pushes from many workers are the normal case.
    """

    def __init__(self, jsonl_path: Optional[str] = None):
        self._lock = threading.Lock()
        self._snapshots: Dict[str, Dict[str, Dict[str, Any]]] = {}
        self._pushes = 0
        self._jsonl_path = jsonl_path
        self._jsonl_file = open(jsonl_path, "a") if jsonl_path else None

    def push(self, node: str, snapshot: Mapping[str, Mapping[str, Any]],
             timestamp: Optional[float] = None) -> int:
        """Store ``node``'s latest snapshot; returns total pushes seen."""
        snapshot = dict(snapshot)
        with self._lock:
            self._snapshots[node] = snapshot
            self._pushes += 1
            pushes = self._pushes
            if self._jsonl_file is not None:
                record = {"node": node,
                          "time": time.time() if timestamp is None
                          else timestamp,
                          "metrics": strip_reservoirs(snapshot)}
                self._jsonl_file.write(json.dumps(record) + "\n")
                self._jsonl_file.flush()
        return pushes

    def snapshot(self) -> Dict[str, Any]:
        """Merged run-wide view: per-node summaries (reservoirs stripped)
        plus cross-node merged metrics."""
        with self._lock:
            per_node = {node: dict(snap)
                        for node, snap in self._snapshots.items()}
            pushes = self._pushes
        return {
            "nodes": {node: strip_reservoirs(snap)
                      for node, snap in per_node.items()},
            "merged": strip_reservoirs(merge_snapshots(per_node)),
            "num_nodes": len(per_node),
            "num_pushes": pushes,
        }

    def nodes(self) -> list:
        with self._lock:
            return sorted(self._snapshots)

    def num_pushes(self) -> int:
        with self._lock:
            return self._pushes

    def report(self) -> str:
        """End-of-run text summary of the merged view."""
        return format_report(self.snapshot())

    def stop(self):
        """Flush and close the JSONL export; aggregated data stays
        readable (run teardown snapshots the hub after stopping it)."""
        with self._lock:
            if self._jsonl_file is not None:
                self._jsonl_file.close()
                self._jsonl_file = None


def format_report(snapshot: Mapping[str, Any]) -> str:
    """Render a hub snapshot as an aligned, human-readable table."""
    lines = [f"=== telemetry: {snapshot['num_nodes']} node(s), "
             f"{snapshot['num_pushes']} push(es) ===",
             "nodes: " + ", ".join(sorted(snapshot["nodes"]))]
    merged = snapshot["merged"]
    if merged:
        width = min(max(len(name) for name in merged), 60)
    for name in sorted(merged):
        entry = merged[name]
        kind = entry["type"]
        if kind == "counter":
            detail = f"count={entry['value']}"
        elif kind == "gauge":
            if "mean" in entry:
                detail = (f"mean={entry['mean']:.3f} "
                          f"min={entry['min']:.3f} max={entry['max']:.3f}")
            else:
                detail = f"value={entry['value']:.3f}"
        else:   # histogram
            if entry.get("count", 0) == 0:
                detail = "count=0"
            else:
                qs = " ".join(f"p{int(q * 100)}={entry[f'p{int(q * 100)}']:.3f}"
                              for q in QUANTILES)
                detail = (f"count={entry['count']} "
                          f"mean={entry['mean']:.3f} {qs} "
                          f"max={entry['max']:.3f}")
        lines.append(f"  {name:<{width}}  {detail}")
    return "\n".join(lines)


class MetricsPusher:
    """Daemon thread pushing this process's registry snapshot to the hub
    every ``period_s``, with a final push on ``stop()`` so short-lived
    workers still report.  A dead or restarting hub must never take down
    the worker: failed pushes are dropped, counted in
    ``telemetry/push_failures``, and logged ONCE per outage (not per
    period).  Because pushes carry the full cumulative snapshot and the
    hub keeps the latest per node, the first successful push after the
    hub returns re-registers this worker with nothing lost but the outage
    window's sampling."""

    def __init__(self, hub, node: str, period_s: float = 0.5):
        self._hub = hub
        self._node = node
        self._period_s = period_s
        self._stop_event = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name=f"metrics-pusher-{node}", daemon=True)
        self._started = False
        self.push_failures = 0
        self._outage = False
        self._m_failures = None

    def start(self) -> "MetricsPusher":
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def _push_once(self):
        try:
            self._hub.push(self._node, _registry.snapshot())
        except Exception as e:
            self.push_failures += 1
            if self._m_failures is None and _registry.enabled():
                self._m_failures = _registry.counter("telemetry/push_failures")
            if self._m_failures:
                self._m_failures.inc()
            if not self._outage:
                self._outage = True
                print(f"[telemetry] {self._node}: hub push failed "
                      f"({type(e).__name__}: {e}) — dropping pushes until "
                      f"the hub returns", file=sys.stderr, flush=True)
            return
        if self._outage:
            self._outage = False
            print(f"[telemetry] {self._node}: hub reachable again after "
                  f"{self.push_failures} dropped pushes — re-registered",
                  file=sys.stderr, flush=True)

    def _run(self):
        while not self._stop_event.wait(self._period_s):
            self._push_once()

    def stop(self, timeout: float = 5.0):
        if not self._started:
            return
        self._stop_event.set()
        self._thread.join(timeout)
        self._push_once()   # final flush AFTER the loop exits: no race


class WorkerTelemetry:
    """Picklable telemetry bootstrap handed to worker nodes.

    Carries the hub handle (a courier ``RemoteHandle`` once pickled into a
    spawn child) plus this worker's node name and push period.
    ``install()`` is called first thing in the worker's ``__init__``:

    - In a fresh spawn child the process registry is unconfigured →
      configure it enabled and start a pusher (returned for teardown).
    - Under the local launcher the parent already configured the process
      and runs its own pusher → no-op, returns None.  (Per-worker node
      attribution is a multiprocess-launcher feature; in-process workers
      share one registry by construction.)
    """

    def __init__(self, hub, node: str, period_s: float = 0.5):
        self.hub = hub
        self.node = node
        self.period_s = period_s

    def install(self) -> Optional[MetricsPusher]:
        if _registry.is_configured():
            return None
        _registry.configure(enabled=True, node=self.node)
        return MetricsPusher(self.hub, self.node, self.period_s).start()
