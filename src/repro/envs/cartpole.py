"""Continuous-control tasks (control-suite-like, §4.3): cartpole swingup and
pendulum swingup with real physics integration, continuous action spaces, and
1000-step episodes with per-step rewards in [0, 1] (100-ish best returns when
scaled, matching the paper's 'theoretical limit' framing)."""
from __future__ import annotations

import numpy as np

from repro.core import types


class CartpoleSwingup(types.Environment):
    """Classic cart-pole swingup from raw features (5-dim obs, 1-dim action)."""

    def __init__(self, seed: int = 0, episode_len: int = 1000):
        self._rng = np.random.RandomState(seed)
        self.episode_len = episode_len
        self.dt = 0.01
        self.masscart, self.masspole, self.length = 1.0, 0.1, 0.5
        self.gravity = 9.8
        self._t = 0
        self._state = None          # x, x_dot, theta, theta_dot

    def observation_spec(self):
        return types.ArraySpec((5,), np.float32, "features")

    def action_spec(self):
        return types.BoundedArraySpec((1,), np.float32, "force", -1.0, 1.0)

    def _obs(self):
        x, xd, th, thd = self._state
        return np.array([x, xd, np.cos(th), np.sin(th), thd], np.float32)

    def reset(self):
        self._t = 0
        self._state = np.array(
            [0.0, 0.0, np.pi + self._rng.uniform(-0.1, 0.1), 0.0])
        return types.restart(self._obs())

    def step(self, action):
        force = 10.0 * float(np.clip(np.asarray(action).reshape(-1)[0], -1, 1))
        x, xd, th, thd = self._state
        for _ in range(2):  # substeps
            total_m = self.masscart + self.masspole
            pm_l = self.masspole * self.length
            sin, cos = np.sin(th), np.cos(th)
            temp = (force + pm_l * thd ** 2 * sin) / total_m
            th_acc = (self.gravity * sin - cos * temp) / (
                self.length * (4.0 / 3.0 - self.masspole * cos ** 2 / total_m))
            x_acc = temp - pm_l * th_acc * cos / total_m
            x += self.dt * xd
            xd += self.dt * x_acc
            th += self.dt * thd
            thd += self.dt * th_acc
            xd *= 0.999
            thd *= 0.999
        x = float(np.clip(x, -2.4, 2.4))
        self._state = np.array([x, xd, th, thd])
        self._t += 1
        # reward: pole upright and cart centered
        upright = (np.cos(th) + 1.0) / 2.0
        centered = 1.0 - abs(x) / 2.4
        reward = float(upright * (0.5 + 0.5 * centered))
        if self._t >= self.episode_len:
            return types.truncation(reward, self._obs())
        return types.transition(reward, self._obs())


class PendulumSwingup(types.Environment):
    """Torque-limited pendulum swingup (3-dim obs, 1-dim action)."""

    def __init__(self, seed: int = 0, episode_len: int = 500):
        self._rng = np.random.RandomState(seed)
        self.episode_len = episode_len
        self.dt = 0.05
        self.g, self.m, self.l = 10.0, 1.0, 1.0
        self.max_torque = 2.0
        self._t = 0
        self._state = None          # theta, theta_dot

    def observation_spec(self):
        return types.ArraySpec((3,), np.float32, "features")

    def action_spec(self):
        return types.BoundedArraySpec((1,), np.float32, "torque", -1.0, 1.0)

    def _obs(self):
        th, thd = self._state
        return np.array([np.cos(th), np.sin(th), thd / 8.0], np.float32)

    def reset(self):
        self._t = 0
        self._state = np.array([np.pi + self._rng.uniform(-0.1, 0.1), 0.0])
        return types.restart(self._obs())

    def step(self, action):
        u = self.max_torque * float(np.clip(np.asarray(action).reshape(-1)[0], -1, 1))
        th, thd = self._state
        thd = thd + (3 * self.g / (2 * self.l) * np.sin(th)
                     + 3.0 / (self.m * self.l ** 2) * u) * self.dt
        thd = float(np.clip(thd, -8, 8))
        th = th + thd * self.dt
        self._state = np.array([th, thd])
        self._t += 1
        reward = float((np.cos(th) + 1.0) / 2.0)
        if self._t >= self.episode_len:
            return types.truncation(reward, self._obs())
        return types.transition(reward, self._obs())
