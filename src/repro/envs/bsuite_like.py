"""bsuite-style capability probes (§4.7): memory chain and a stochastic bandit.

MemoryChain: the first observation contains a context bit; after N distractor
steps the agent must report it — only agents with memory (R2D2) can solve it.
Bandit: a single-step stochastic bandit probing basic credit assignment.
"""
from __future__ import annotations

import numpy as np

from repro.core import types


class MemoryChain(types.Environment):
    def __init__(self, memory_length: int = 10, seed: int = 0):
        self.memory_length = memory_length
        self._rng = np.random.RandomState(seed)
        self._context = 0
        self._t = 0
        self._done = True

    def observation_spec(self):
        # [context (only at t=0), time fraction, query flag]
        return types.ArraySpec((3,), np.float32, "obs")

    def action_spec(self):
        return types.DiscreteArraySpec((), np.int32, "action", num_values=2)

    def _obs(self):
        ctx = self._context if self._t == 0 else 0.0
        query = 1.0 if self._t == self.memory_length else 0.0
        return np.array([ctx, self._t / self.memory_length, query], np.float32)

    def reset(self):
        self._context = int(self._rng.randint(2)) * 2 - 1   # -1 or +1
        self._t = 0
        self._done = False
        return types.restart(self._obs())

    def step(self, action):
        if self._done:
            return self.reset()
        self._t += 1
        if self._t == self.memory_length:
            self._done = True
            correct = (int(action) * 2 - 1) == self._context
            return types.termination(1.0 if correct else -1.0, self._obs())
        return types.transition(0.0, self._obs())


class Bandit(types.Environment):
    def __init__(self, num_arms: int = 11, seed: int = 0):
        self.num_arms = num_arms
        self._rng = np.random.RandomState(seed)
        self.means = np.linspace(0, 1, num_arms)
        self._rng.shuffle(self.means)

    def observation_spec(self):
        return types.ArraySpec((1,), np.float32, "obs")

    def action_spec(self):
        return types.DiscreteArraySpec((), np.int32, "action",
                                       num_values=self.num_arms)

    def reset(self):
        return types.restart(np.zeros(1, np.float32))

    def step(self, action):
        r = float(self._rng.rand() < self.means[int(action)])
        return types.termination(r, np.zeros(1, np.float32))
