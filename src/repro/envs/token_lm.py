"""Token-MDP environment: the bridge between the RL framework and the
large-model zoo.  The 'environment' emits token observations from a synthetic
Markov language (a random n-gram chain); actions are next-token predictions
and reward is log-likelihood-style (+1 exact match, partial credit by chain
proximity).  This is the environment used by the transformer-policy examples
and the offline-dataset generator for the BC learner.
"""
from __future__ import annotations

import numpy as np

from repro.core import types


class TokenChain(types.Environment):
    def __init__(self, vocab_size: int = 256, order: int = 2,
                 episode_len: int = 64, seed: int = 0):
        self.vocab = vocab_size
        self.order = order
        self.episode_len = episode_len
        rng = np.random.RandomState(seed)
        # deterministic successor table: context hash -> next token
        self._succ = rng.randint(0, vocab_size, size=(vocab_size * order,))
        self._ctx = None
        self._t = 0

    def observation_spec(self):
        return types.ArraySpec((self.order,), np.int32, "context")

    def action_spec(self):
        return types.DiscreteArraySpec((), np.int32, "action",
                                       num_values=self.vocab)

    def _next_token(self):
        h = 0
        for i, t in enumerate(self._ctx):
            h = (h + (i + 1) * int(t)) % (self.vocab * self.order)
        return int(self._succ[h])

    def reset(self):
        self._ctx = np.zeros(self.order, np.int32)
        self._t = 0
        return types.restart(self._ctx.copy())

    def step(self, action):
        target = self._next_token()
        reward = 1.0 if int(action) == target else 0.0
        self._ctx = np.roll(self._ctx, -1)
        self._ctx[-1] = target
        self._t += 1
        if self._t >= self.episode_len:
            return types.termination(reward, self._ctx.copy())
        return types.transition(reward, self._ctx.copy())
