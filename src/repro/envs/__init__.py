from repro.envs.bsuite_like import Bandit, MemoryChain  # noqa: F401
from repro.envs.cartpole import CartpoleSwingup, PendulumSwingup  # noqa: F401
from repro.envs.catch import Catch  # noqa: F401
from repro.envs.deep_sea import DeepSea  # noqa: F401
from repro.envs.token_lm import TokenChain  # noqa: F401
from repro.envs.vector import VectorEnv, split_timestep, stack_timesteps  # noqa: F401
