"""Catch (bsuite): a falling ball must be caught by a paddle. Discrete."""
from __future__ import annotations

import numpy as np

from repro.core import types


class Catch(types.Environment):
    def __init__(self, rows: int = 10, columns: int = 5, seed: int = 0):
        self.rows, self.columns = rows, columns
        self._rng = np.random.RandomState(seed)
        self._ball = None
        self._paddle = None
        self._done = True

    def observation_spec(self):
        return types.ArraySpec((self.rows, self.columns), np.float32, "board")

    def action_spec(self):
        return types.DiscreteArraySpec((), np.int32, "action", num_values=3)

    def _board(self):
        b = np.zeros((self.rows, self.columns), np.float32)
        r, c = self._ball
        if r < self.rows:
            b[r, c] = 1.0
        b[self.rows - 1, self._paddle] = 1.0
        return b

    def reset(self):
        self._ball = [0, int(self._rng.randint(self.columns))]
        self._paddle = self.columns // 2
        self._done = False
        return types.restart(self._board())

    def step(self, action):
        if self._done:
            return self.reset()
        self._paddle = int(np.clip(self._paddle + int(action) - 1,
                                   0, self.columns - 1))
        self._ball[0] += 1
        if self._ball[0] == self.rows - 1:
            self._done = True
            reward = 1.0 if self._ball[1] == self._paddle else -1.0
            return types.termination(reward, self._board())
        return types.transition(0.0, self._board())
