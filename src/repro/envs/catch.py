"""Catch (bsuite): a falling ball must be caught by a paddle. Discrete."""
from __future__ import annotations

import numpy as np

from repro.core import types


class Catch(types.Environment):
    def __init__(self, rows: int = 10, columns: int = 5, seed: int = 0):
        self.rows, self.columns = rows, columns
        self._rng = np.random.RandomState(seed)
        self._ball = None
        self._paddle = None
        self._done = True

    def observation_spec(self):
        return types.ArraySpec((self.rows, self.columns), np.float32, "board")

    def action_spec(self):
        return types.DiscreteArraySpec((), np.int32, "action", num_values=3)

    def _board(self):
        b = np.zeros((self.rows, self.columns), np.float32)
        r, c = self._ball
        if r < self.rows:
            b[r, c] = 1.0
        b[self.rows - 1, self._paddle] = 1.0
        return b

    def reset(self):
        self._ball = [0, int(self._rng.randint(self.columns))]
        self._paddle = self.columns // 2
        self._done = False
        return types.restart(self._board())

    # -- exact resume (repro.resilience) -------------------------------
    def get_state(self):
        """Everything a bit-exact resume needs: the ball-column RNG stream
        and the board position (captured at episode boundaries, where
        done=True and ball/paddle are about to be re-rolled)."""
        return {"rng": self._rng.get_state(),
                "ball": None if self._ball is None else list(self._ball),
                "paddle": self._paddle,
                "done": self._done}

    def set_state(self, state):
        self._rng.set_state(state["rng"])
        self._ball = None if state["ball"] is None else list(state["ball"])
        self._paddle = state["paddle"]
        self._done = state["done"]

    def step(self, action):
        if self._done:
            return self.reset()
        self._paddle = int(np.clip(self._paddle + int(action) - 1,
                                   0, self.columns - 1))
        self._ball[0] += 1
        if self._ball[0] == self.rows - 1:
            self._done = True
            reward = 1.0 if self._ball[1] == self._paddle else -1.0
            return types.termination(reward, self._board())
        return types.transition(0.0, self._board())
