"""Vectorized environments: step N copies of any env with stacked arrays.

``VectorEnv`` holds N independent instances built from one ``env_factory``
(each with its own seed) and exposes a batched ``reset``/``step`` whose
``TimeStep`` fields are stacked along a leading ``num_envs`` axis.  This is
the environment half of the batched acting pipeline: a batched actor
evaluates ONE vmapped policy call per ``step`` instead of N per-env calls.

Auto-reset contract
-------------------
An env whose previous timestep was LAST is *reset* (not stepped) on the next
``step`` call: its slot carries ``StepType.FIRST``, reward 0 and discount 1
(batched arrays cannot hold ``None``), and the action passed for that slot
is ignored.  The terminal observation is therefore always delivered before
the reset observation — per-env streams are indistinguishable from a
single-env ``reset``/``step`` loop, which is what the vectorized loop relies
on to route ``add_first`` vs ``add`` to per-env adders.

``split_timestep`` recovers the per-env ``TimeStep`` view (reward/discount
become ``None`` again on FIRST steps, matching the dm_env convention).
"""
from __future__ import annotations

from typing import Callable, List

import numpy as np

from repro.core import types


def stack_timesteps(steps: List[types.TimeStep]) -> types.TimeStep:
    """Stack per-env timesteps into one batched TimeStep (arrays only)."""
    return types.TimeStep(
        step_type=np.asarray([int(ts.step_type) for ts in steps], np.int32),
        reward=np.asarray([0.0 if ts.reward is None else ts.reward
                           for ts in steps], np.float32),
        discount=np.asarray([1.0 if ts.discount is None else ts.discount
                             for ts in steps], np.float32),
        observation=np.stack([np.asarray(ts.observation) for ts in steps]),
    )


def split_timestep(batched: types.TimeStep, index: int) -> types.TimeStep:
    """The per-env view of slot ``index`` (None reward/discount on FIRST)."""
    step_type = types.StepType(int(batched.step_type[index]))
    if step_type == types.StepType.FIRST:
        return types.TimeStep(step_type, None, None,
                              batched.observation[index])
    return types.TimeStep(step_type,
                          float(batched.reward[index]),
                          float(batched.discount[index]),
                          batched.observation[index])


class VectorEnv(types.Environment):
    """N copies of ``env_factory`` stepped together with auto-reset.

    ``observation_spec``/``action_spec`` describe a SINGLE member env — they
    are what per-example policies and adders see (the batch axis is an
    execution detail, not part of the environment contract).
    """

    def __init__(self, env_factory: Callable[[int], types.Environment],
                 num_envs: int, seed: int = 0):
        if num_envs < 1:
            raise ValueError(f"num_envs must be >= 1, got {num_envs}")
        self._envs = [env_factory(seed + i) for i in range(num_envs)]
        self._needs_reset = np.ones(num_envs, bool)

    @property
    def num_envs(self) -> int:
        return len(self._envs)

    @property
    def envs(self) -> List[types.Environment]:
        return list(self._envs)

    def reset(self) -> types.TimeStep:
        self._needs_reset[:] = False
        return stack_timesteps([env.reset() for env in self._envs])

    def step(self, actions) -> types.TimeStep:
        actions = np.asarray(actions)
        if len(actions) != len(self._envs):
            raise ValueError(
                f"expected {len(self._envs)} actions, got {len(actions)}")
        steps = []
        for i, env in enumerate(self._envs):
            if self._needs_reset[i]:
                # auto-reset: the action for this slot is ignored
                self._needs_reset[i] = False
                steps.append(env.reset())
                continue
            ts = env.step(actions[i])
            if ts.last():
                self._needs_reset[i] = True
            steps.append(ts)
        return stack_timesteps(steps)

    # -- exact resume (repro.resilience) -------------------------------
    def get_state(self):
        """Member env states (None for envs without ``get_state``) + the
        auto-reset mask — what a run-wide checkpoint captures so a resumed
        vectorized loop continues mid-flight episodes instead of resetting
        every slot."""
        return {"envs": [getattr(env, "get_state", lambda: None)()
                         for env in self._envs],
                "needs_reset": self._needs_reset.copy()}

    def set_state(self, state):
        for env, env_state in zip(self._envs, state["envs"]):
            if env_state is not None and hasattr(env, "set_state"):
                env.set_state(env_state)
        self._needs_reset[:] = np.asarray(state["needs_reset"], bool)

    def observation_spec(self):
        return self._envs[0].observation_spec()

    def action_spec(self):
        return self._envs[0].action_spec()

    def reward_spec(self):
        return self._envs[0].reward_spec()

    def discount_spec(self):
        return self._envs[0].discount_spec()

    def close(self):
        for env in self._envs:
            env.close()
