"""Deep Sea (bsuite): the canonical hard-exploration task (§4.8 of the paper).

An NxN grid; the agent starts top-left, always descends one row, and moves
left/right.  Only the far-right bottom cell pays +1; every 'right' move costs
0.01/N.  Random policies find the treasure with probability 2^-N.  The
stochastic variant flips the effective action with probability 1/N.
"""
from __future__ import annotations

import numpy as np

from repro.core import types


class DeepSea(types.Environment):
    def __init__(self, size: int = 10, stochastic: bool = False, seed: int = 0):
        self.size = size
        self.stochastic = stochastic
        self._rng = np.random.RandomState(seed)
        # fixed random action mapping per column (as in bsuite)
        self._action_map = self._rng.binomial(1, 0.5, (size, size))
        self._row = 0
        self._col = 0
        self._done = True

    def observation_spec(self):
        return types.ArraySpec((self.size, self.size), np.float32, "grid")

    def action_spec(self):
        return types.DiscreteArraySpec((), np.int32, "action", num_values=2)

    def _obs(self):
        o = np.zeros((self.size, self.size), np.float32)
        if self._row < self.size:
            o[self._row, self._col] = 1.0
        return o

    def reset(self):
        self._row = self._col = 0
        self._done = False
        return types.restart(self._obs())

    def optimal_action(self) -> int:
        """The action whose mapped effect is 'right' in the current cell."""
        go_right = 1
        mapped = self._action_map[self._row, self._col]
        return int(go_right == mapped)

    def step(self, action):
        if self._done:
            return self.reset()
        a = int(action)
        # action semantics per-cell (bsuite's action mapping)
        go_right = (a == self._action_map[self._row, self._col])
        if self.stochastic and self._rng.rand() < 1.0 / self.size:
            go_right = not go_right
        reward = 0.0
        if go_right:
            reward -= 0.01 / self.size
            self._col = min(self._col + 1, self.size - 1)
        else:
            self._col = max(self._col - 1, 0)
        self._row += 1
        if self._row == self.size:
            self._done = True
            if go_right and self._col == self.size - 1:
                reward += 1.0
            return types.termination(reward, self._obs())
        return types.transition(reward, self._obs())
