"""Loggers (the §4.2 measurement apparatus): terminal, CSV, in-memory, and
fan-out — pluggable anywhere a ``logger`` callable is accepted (environment
loops, learners, evaluators)."""
from __future__ import annotations

import csv
import numbers
import os
import threading
import time
from typing import Any, Dict, List, Optional


def _format_value(v: Any) -> str:
    """``:.3f`` for any non-integral real number — including numpy float
    scalars, which are not ``float`` instances and would otherwise print as
    raw reprs like ``0.12300000339746475``."""
    if isinstance(v, numbers.Real) and not isinstance(v, numbers.Integral):
        return f"{float(v):.3f}"
    return str(v)


class TerminalLogger:
    def __init__(self, label: str = "", every_s: float = 0.0):
        self.label = label
        self.every_s = every_s
        self._last = 0.0

    def __call__(self, values: Dict[str, Any]):
        now = time.time()
        if now - self._last < self.every_s:
            return
        self._last = now
        items = ", ".join(f"{k}={_format_value(v)}"
                          for k, v in sorted(values.items()))
        print(f"[{self.label}] {items}", flush=True)


class CSVLogger:
    """Appends rows; writes the header from the first row's keys."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._lock = threading.Lock()
        self._fieldnames: Optional[List[str]] = None

    def __call__(self, values: Dict[str, Any]):
        with self._lock:
            new = not os.path.exists(self.path)
            if self._fieldnames is None:
                if new:
                    self._fieldnames = sorted(values)
                else:
                    with open(self.path) as f:
                        try:
                            self._fieldnames = next(csv.reader(f))
                        except StopIteration:
                            # existing but EMPTY file (e.g. created by
                            # ``touch`` or a crashed run): treat as new
                            self._fieldnames = sorted(values)
                            new = True
            with open(self.path, "a", newline="") as f:
                w = csv.DictWriter(f, self._fieldnames, extrasaction="ignore")
                if new:
                    w.writeheader()
                w.writerow(values)


class InMemoryLogger:
    def __init__(self):
        self.rows: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    def __call__(self, values: Dict[str, Any]):
        with self._lock:
            self.rows.append(dict(values))


class Dispatcher:
    def __init__(self, *loggers):
        self.loggers = loggers

    def __call__(self, values: Dict[str, Any]):
        for lg in self.loggers:
            lg(values)
