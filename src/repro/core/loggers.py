"""Loggers (the §4.2 measurement apparatus): terminal, CSV, in-memory, and
fan-out — pluggable anywhere a ``logger`` callable is accepted (environment
loops, learners, evaluators)."""
from __future__ import annotations

import csv
import os
import threading
import time
from typing import Any, Dict, List, Optional


class TerminalLogger:
    def __init__(self, label: str = "", every_s: float = 0.0):
        self.label = label
        self.every_s = every_s
        self._last = 0.0

    def __call__(self, values: Dict[str, Any]):
        now = time.time()
        if now - self._last < self.every_s:
            return
        self._last = now
        items = ", ".join(f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
                          for k, v in sorted(values.items()))
        print(f"[{self.label}] {items}", flush=True)


class CSVLogger:
    """Appends rows; writes the header from the first row's keys."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._lock = threading.Lock()
        self._fieldnames: Optional[List[str]] = None

    def __call__(self, values: Dict[str, Any]):
        with self._lock:
            new = not os.path.exists(self.path)
            if self._fieldnames is None:
                if new:
                    self._fieldnames = sorted(values)
                else:
                    with open(self.path) as f:
                        self._fieldnames = next(csv.reader(f))
            with open(self.path, "a", newline="") as f:
                w = csv.DictWriter(f, self._fieldnames, extrasaction="ignore")
                if new:
                    w.writeheader()
                w.writerow(values)


class InMemoryLogger:
    def __init__(self):
        self.rows: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    def __call__(self, values: Dict[str, Any]):
        with self._lock:
            self.rows.append(dict(values))


class Dispatcher:
    def __init__(self, *loggers):
        self.loggers = loggers

    def __call__(self, values: Dict[str, Any]):
        for lg in self.loggers:
            lg(values)
