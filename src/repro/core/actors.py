"""Generic actors (§2.3): feed-forward and recurrent.

A ``FeedForwardActor`` evaluates a jitted policy function and forwards its
observations to an adder; a ``RecurrentActor`` additionally threads a
recurrent core state between ``select_action`` calls and stores the state at
sequence starts (R2D2's stale-state mechanism).  Both pull weights from a
``VariableClient`` on ``update()`` — they never own the learner.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from typing import TYPE_CHECKING

from repro.core.interfaces import Actor
from repro.core.types import TimeStep
from repro.core.variable import VariableClient

if TYPE_CHECKING:  # avoid core <-> adders circular import at runtime
    from repro.adders.base import Adder

PolicyFn = Callable[..., Any]   # (params, key, obs) -> action


class FeedForwardActor(Actor):
    def __init__(self, policy: PolicyFn, variable_client: VariableClient,
                 adder: Optional["Adder"] = None, rng_seed: int = 0,
                 jit: bool = True):
        self._policy = jax.jit(policy) if jit else policy
        self._client = variable_client
        self._adder = adder
        self._key = jax.random.key(rng_seed)

    def select_action(self, observation):
        self._key, sub = jax.random.split(self._key)
        action = self._policy(self._client.params, sub,
                              jnp.asarray(observation))
        return np.asarray(action)

    def observe_first(self, timestep: TimeStep):
        if self._adder:
            self._adder.add_first(timestep)

    def observe(self, action, next_timestep: TimeStep):
        if self._adder:
            self._adder.add(action, next_timestep)

    def update(self, wait: bool = False):
        self._client.update(wait)


class RecurrentActor(Actor):
    def __init__(self, policy: PolicyFn, initial_state_fn: Callable[[], Any],
                 variable_client: VariableClient,
                 adder: Optional["Adder"] = None, rng_seed: int = 0,
                 store_state: bool = True, jit: bool = True):
        self._policy = jax.jit(policy) if jit else policy
        self._initial_state_fn = initial_state_fn
        self._client = variable_client
        self._adder = adder
        self._key = jax.random.key(rng_seed)
        self._state = None
        self._prev_state = None
        self._store_state = store_state

    def select_action(self, observation):
        if self._state is None:
            self._state = self._initial_state_fn()
        self._key, sub = jax.random.split(self._key)
        self._prev_state = self._state
        action, self._state = self._policy(self._client.params, sub,
                                           jnp.asarray(observation), self._state)
        return np.asarray(action)

    def observe_first(self, timestep: TimeStep):
        self._state = self._initial_state_fn()
        if self._adder:
            extras = ()
            if self._store_state:
                extras = jax.tree.map(np.asarray, self._state)
            if hasattr(self._adder, "add_first") and isinstance(
                    getattr(self._adder, "add_first"), Callable):
                try:
                    self._adder.add_first(timestep, extras)   # sequence adder
                except TypeError:
                    self._adder.add_first(timestep)

    def observe(self, action, next_timestep: TimeStep):
        if self._adder:
            self._adder.add(action, next_timestep)

    def update(self, wait: bool = False):
        self._client.update(wait)
