"""Generic actors (§2.3): feed-forward, recurrent, and their batched forms.

A ``FeedForwardActor`` evaluates a jitted policy function and forwards its
observations to an adder; a ``RecurrentActor`` additionally threads a
recurrent core state between ``select_action`` calls and stores the state at
sequence starts (R2D2's stale-state mechanism).  Both pull weights from a
``VariableClient`` on ``update()`` — they never own the learner.

RNG lives on the device: every actor keeps a fixed base key and derives the
per-step key INSIDE the jitted call via ``fold_in`` on a host-side step
counter, so selecting an action costs exactly one dispatch (no host-side
``jax.random.split`` per step).

``BatchedFeedForwardActor``/``BatchedRecurrentActor`` drive N environments
through ONE ``jax.vmap``-ed, jitted policy call per step — the actor half of
the vectorized acting pipeline (``repro.envs.vector.VectorEnv`` +
``VectorizedEnvironmentLoop``).  They fan transitions out to N per-env
adders via the ``env_id`` argument on ``observe``/``observe_first``.

``InferenceClientActor`` is the SEED-style client: ``select_action`` is an
RPC to a central ``InferenceServer`` that coalesces requests from many actor
workers into one batched forward pass; the client holds no weights at all.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from typing import TYPE_CHECKING

from repro.core.interfaces import Actor
from repro.core.types import TimeStep
from repro.core.variable import VariableClient

if TYPE_CHECKING:  # avoid core <-> adders circular import at runtime
    from repro.adders.base import Adder

PolicyFn = Callable[..., Any]   # (params, key, obs) -> action

# Step counters fed to the jitted fold_in are traced as int32 — wrap before
# they overflow (key reuse after 2**31 steps is statistically harmless).
STEP_MOD = 2 ** 31


def adder_takes_extras(adder) -> bool:
    """Whether ``adder.add_first`` accepts a second ``extras`` argument.

    Prefers the adder's declared ``supports_extras`` attribute; falls back to
    an ``inspect.signature`` arity check for third-party adders.  This is an
    explicit capability probe — unlike calling ``add_first`` inside a
    ``try/except TypeError``, it can never swallow a real ``TypeError``
    raised by the adder's own implementation.
    """
    if adder is None:
        return False
    declared = getattr(adder, "supports_extras", None)
    if declared is not None:
        return bool(declared)
    try:
        params = inspect.signature(adder.add_first).parameters
    except (TypeError, ValueError):
        return False
    positional = [p for p in params.values()
                  if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    has_var = any(p.kind == p.VAR_POSITIONAL for p in params.values())
    return len(positional) >= 2 or has_var


def _folded_policy(policy: PolicyFn):
    """(params, base_key, step, *rest) — per-step key derived on device."""

    def run(params, base_key, step, *rest):
        return policy(params, jax.random.fold_in(base_key, step), *rest)

    return run


def _batched_policy(policy: PolicyFn):
    """vmap ``policy`` over a leading env axis with per-env device keys.

    One call evaluates N policy instances: the per-step key is folded in on
    the device, split into N per-env keys, and mapped alongside the stacked
    observations (and any recurrent state) — params are broadcast.
    """

    def run(params, base_key, step, obs, *rest):
        key = jax.random.fold_in(base_key, step)
        keys = jax.random.split(key, obs.shape[0])
        in_axes = (None, 0, 0) + (0,) * len(rest)
        return jax.vmap(policy, in_axes=in_axes)(params, keys, obs, *rest)

    return run


class FeedForwardActor(Actor):
    def __init__(self, policy: PolicyFn, variable_client: VariableClient,
                 adder: Optional["Adder"] = None, rng_seed: int = 0,
                 jit: bool = True):
        fn = _folded_policy(policy)
        self._policy = jax.jit(fn) if jit else fn
        self._client = variable_client
        self._adder = adder
        self._key = jax.random.key(rng_seed)
        self._steps = 0

    def select_action(self, observation):
        action = self._policy(self._client.params, self._key, self._steps,
                              jnp.asarray(observation))
        self._steps = (self._steps + 1) % STEP_MOD
        return np.asarray(action)

    def observe_first(self, timestep: TimeStep):
        if self._adder:
            self._adder.add_first(timestep)

    def observe(self, action, next_timestep: TimeStep):
        if self._adder:
            self._adder.add(action, next_timestep)

    def update(self, wait: bool = False):
        self._client.update(wait)

    def state_dict(self):
        # _steps is the whole RNG stream: per-step keys are
        # fold_in(base_key, step), and base_key is rebuilt from the seed.
        return {"steps": self._steps, "client": self._client.state_dict()}

    def load_state_dict(self, state):
        self._steps = int(state["steps"])
        self._client.load_state_dict(state["client"])


class RecurrentActor(Actor):
    def __init__(self, policy: PolicyFn, initial_state_fn: Callable[[], Any],
                 variable_client: VariableClient,
                 adder: Optional["Adder"] = None, rng_seed: int = 0,
                 store_state: bool = True, jit: bool = True):
        fn = _folded_policy(policy)
        self._policy = jax.jit(fn) if jit else fn
        self._initial_state_fn = initial_state_fn
        self._client = variable_client
        self._adder = adder
        self._adder_extras = adder_takes_extras(adder)
        self._key = jax.random.key(rng_seed)
        self._steps = 0
        self._state = None
        self._prev_state = None
        self._store_state = store_state

    def select_action(self, observation):
        if self._state is None:
            self._state = self._initial_state_fn()
        self._prev_state = self._state
        action, self._state = self._policy(self._client.params, self._key,
                                           self._steps,
                                           jnp.asarray(observation),
                                           self._state)
        self._steps = (self._steps + 1) % STEP_MOD
        return np.asarray(action)

    def observe_first(self, timestep: TimeStep):
        self._state = self._initial_state_fn()
        if self._adder:
            if self._adder_extras and self._store_state:
                extras = jax.tree.map(np.asarray, self._state)
                self._adder.add_first(timestep, extras)   # sequence adder
            else:
                self._adder.add_first(timestep)

    def observe(self, action, next_timestep: TimeStep):
        if self._adder:
            self._adder.add(action, next_timestep)

    def update(self, wait: bool = False):
        self._client.update(wait)

    def state_dict(self):
        # Captured at an episode boundary, so the recurrent core state is
        # about to be re-initialized by observe_first — only the RNG step
        # counter and weight-fetch cadence need to survive.
        return {"steps": self._steps, "client": self._client.state_dict()}

    def load_state_dict(self, state):
        self._steps = int(state["steps"])
        self._client.load_state_dict(state["client"])


class BatchedFeedForwardActor(Actor):
    """N environments, ONE vmapped+jitted policy dispatch per step.

    ``select_action`` takes stacked observations ``(N, ...)`` and returns N
    actions; ``observe``/``observe_first`` route each env's transitions to
    its own adder (``adders[env_id]``) so per-env experience streams are
    byte-identical to N single-env loops.
    """

    def __init__(self, policy: PolicyFn, variable_client: VariableClient,
                 adders: Optional[Sequence[Optional["Adder"]]] = None,
                 rng_seed: int = 0, jit: bool = True):
        fn = _batched_policy(policy)
        self._policy = jax.jit(fn) if jit else fn
        self._client = variable_client
        self._adders = list(adders) if adders is not None else []
        self._key = jax.random.key(rng_seed)
        self._steps = 0

    def _adder(self, env_id: int) -> Optional["Adder"]:
        return self._adders[env_id] if env_id < len(self._adders) else None

    def _run_policy(self, observation):
        out = self._policy(self._client.params, self._key, self._steps,
                           jnp.asarray(observation))
        self._steps = (self._steps + 1) % STEP_MOD
        return out

    def select_action(self, observation):
        return np.asarray(self._run_policy(observation))

    def observe_first(self, timestep: TimeStep, env_id: int = 0):
        adder = self._adder(env_id)
        if adder:
            adder.add_first(timestep)

    def observe(self, action, next_timestep: TimeStep, env_id: int = 0):
        adder = self._adder(env_id)
        if adder:
            adder.add(action, next_timestep)

    def update(self, wait: bool = False):
        self._client.update(wait)

    def state_dict(self):
        return {"steps": self._steps, "client": self._client.state_dict()}

    def load_state_dict(self, state):
        self._steps = int(state["steps"])
        self._client.load_state_dict(state["client"])


class BatchedRecurrentActor(BatchedFeedForwardActor):
    """Batched recurrent acting: stacked core state ``(N, ...)`` threaded
    through one vmapped call; per-env state resets on that env's
    ``observe_first`` (the auto-reset boundary)."""

    def __init__(self, policy: PolicyFn, initial_state_fn: Callable[[], Any],
                 variable_client: VariableClient,
                 adders: Optional[Sequence[Optional["Adder"]]] = None,
                 rng_seed: int = 0, store_state: bool = True,
                 jit: bool = True):
        super().__init__(policy, variable_client, adders, rng_seed, jit)
        self._initial_state_fn = initial_state_fn
        self._store_state = store_state
        self._state = None
        self._adders_extras = [adder_takes_extras(a) for a in self._adders]

    def _stacked_initial_state(self, num_envs: int):
        init = self._initial_state_fn()
        return jax.tree.map(
            lambda x: jnp.stack([jnp.asarray(x)] * num_envs), init)

    def _state_slice(self, env_id: int):
        return jax.tree.map(lambda s: s[env_id], self._state)

    def select_action(self, observation):
        observation = jnp.asarray(observation)
        if self._state is None:
            self._state = self._stacked_initial_state(observation.shape[0])
        actions, self._state = self._policy(
            self._client.params, self._key, self._steps, observation,
            self._state)
        self._steps = (self._steps + 1) % STEP_MOD
        return np.asarray(actions)

    def observe_first(self, timestep: TimeStep, env_id: int = 0):
        if self._state is not None:
            # reset just this env's slice of the stacked core state
            init = self._initial_state_fn()
            self._state = jax.tree.map(
                lambda s, i: s.at[env_id].set(jnp.asarray(i)),
                self._state, init)
        adder = self._adder(env_id)
        if adder:
            if (env_id < len(self._adders_extras)
                    and self._adders_extras[env_id] and self._store_state):
                extras = jax.tree.map(np.asarray, self._initial_state_fn())
                adder.add_first(timestep, extras)
            else:
                adder.add_first(timestep)


class InferenceClientActor(Actor):
    """SEED-style actor: policy evaluation lives in a remote
    ``InferenceServer``; this client only steps environments and feeds
    adders.

    ``inference`` is any handle exposing ``select_action(observations)``
    with a leading batch axis — the in-memory ``Handle`` under the local
    launcher, a courier ``RemoteHandle`` under multiprocess.  ``update`` is
    a no-op: the server owns the weights and refreshes them itself.
    """

    def __init__(self, inference,
                 adder: Optional["Adder"] = None,
                 adders: Optional[Sequence[Optional["Adder"]]] = None,
                 batched: bool = False):
        if adder is not None and adders is not None:
            raise ValueError("pass either adder= or adders=, not both")
        self._inference = inference
        self._adders = list(adders) if adders is not None \
            else ([adder] if adder is not None else [])
        self._batched = batched

    def _adder(self, env_id: int) -> Optional["Adder"]:
        return self._adders[env_id] if env_id < len(self._adders) else None

    def select_action(self, observation):
        obs = np.asarray(observation)
        if not self._batched:
            obs = obs[None]
        actions = np.asarray(self._inference.select_action(obs))
        return actions if self._batched else actions[0]

    def observe_first(self, timestep: TimeStep, env_id: int = 0):
        adder = self._adder(env_id)
        if adder:
            adder.add_first(timestep)

    def observe(self, action, next_timestep: TimeStep, env_id: int = 0):
        adder = self._adder(env_id)
        if adder:
            adder.add(action, next_timestep)

    def update(self, wait: bool = False):
        pass   # the InferenceServer owns and refreshes the weights
