"""Variable distribution: the learner is a VariableSource; actors poll it
through a VariableClient (Fig 4's proxy-actor pattern — pull, not push)."""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, List, Optional, Sequence

from repro.core.interfaces import VariableSource


class VariableClient:
    def __init__(self, source: VariableSource, names: Sequence[str] = ("policy",),
                 update_period: int = 1):
        self._source = source
        self._names = tuple(names)
        self._period = max(int(update_period), 1)
        self._calls = 0
        self._params: Optional[List[Any]] = None

    @property
    def params(self):
        if self._params is None:
            self.update_and_wait()
        return self._params[0] if len(self._names) == 1 else self._params

    def update(self, wait: bool = False):
        """Poll the source every `update_period` calls (async in real Acme;
        synchronous here — the call itself is cheap in-process)."""
        self._calls += 1
        if wait or self._params is None or self._calls % self._period == 0:
            self.update_and_wait()

    def update_and_wait(self):
        self._params = self._source.get_variables(self._names)


class VariableServer(VariableSource):
    """Thread-safe holder used by learners to publish weights."""

    def __init__(self, **named_vars):
        self._lock = threading.Lock()
        self._vars = dict(named_vars)

    def publish(self, name: str, value):
        with self._lock:
            self._vars[name] = value

    def get_variables(self, names: Sequence[str] = ()):
        with self._lock:
            if not names:
                names = list(self._vars)
            return [self._vars[n] for n in names]
