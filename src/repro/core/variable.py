"""Variable distribution: the learner is a VariableSource; actors poll it
through a VariableClient (Fig 4's proxy-actor pattern — pull, not push).

The source may be the learner object itself, an in-memory program ``Handle``
to it, or a courier ``RemoteHandle`` when the actor lives in another process
— the client only ever calls ``get_variables`` and cannot tell the
difference.  ``serve_variable_source`` is the one-liner that exports any
``VariableSource`` over courier RPC.
"""
from __future__ import annotations

import threading
from typing import Any, List, Optional, Sequence

from repro.core.interfaces import VariableSource


class VariableClient:
    def __init__(self, source, names: Sequence[str] = ("policy",),
                 update_period: int = 1):
        self._source = source
        self._names = tuple(names)
        self._period = max(int(update_period), 1)
        self._calls = 0
        self._params: Optional[List[Any]] = None
        self._fresh = False

    @property
    def params(self):
        if self._params is None:
            self.update_and_wait()
            # the fetch just happened — the next update() call is satisfied
            # already and must not hit the source a second time.
            self._fresh = True
        return self._params[0] if len(self._names) == 1 else self._params

    def update(self, wait: bool = False):
        """Poll the source every `update_period` calls (async in real Acme;
        synchronous here — over courier the call is a real RPC, so the
        period is what bounds actor-side traffic)."""
        self._calls += 1
        if wait:
            self.update_and_wait()
            return
        if self._fresh:
            # params were just populated by the property accessor on this
            # very step; skip the redundant initial re-fetch.
            self._fresh = False
            return
        if self._params is None or self._calls % self._period == 0:
            self.update_and_wait()

    def update_and_wait(self):
        self._params = self._source.get_variables(self._names)
        self._fresh = False

    # -- exact resume (repro.resilience) -------------------------------
    def state_dict(self) -> dict:
        # Two things must survive: the fetch cadence (_calls % _period
        # decides WHEN weights refresh) and the cached params themselves —
        # with update_period > 1 the cache is legitimately STALER than the
        # learner at checkpoint time, and refetching on resume would hand
        # the actor fresher weights than the uninterrupted run used.
        params = self._params
        if params is not None:
            import jax
            import numpy as np
            params = jax.tree.map(np.asarray, params)
        return {"calls": self._calls, "params": params,
                "fresh": self._fresh}

    def load_state_dict(self, state: dict):
        self._calls = int(state["calls"])
        self._params = state.get("params")
        self._fresh = bool(state.get("fresh", False))


class VariableServer(VariableSource):
    """Thread-safe holder used by learners to publish weights.

    ``get_variables`` with empty/omitted ``names`` returns ALL published
    variables (insertion order) — consistent with ``VariableClient``'s
    named-subset requests, which always pass explicit names.
    """

    def __init__(self, **named_vars):
        self._lock = threading.Lock()
        self._vars = dict(named_vars)

    def publish(self, name: str, value):
        with self._lock:
            self._vars[name] = value

    def get_variables(self, names: Sequence[str] = ()):
        with self._lock:
            if not names:
                names = list(self._vars)
            return [self._vars[n] for n in names]


def serve_variable_source(source: VariableSource, name: str = "variables"):
    """Export ``source`` over a courier server; returns ``(server, handle)``.

    The handle is a picklable RPC stub restricted to ``get_variables`` —
    hand it to actors in other processes as their ``VariableClient`` source.
    """
    from repro.distributed.courier import serve
    return serve(source, interface=("get_variables",), name=name)
