"""SEED-style batched inference service (the Tier-2 half of batched acting).

Remote actor workers stop evaluating the policy themselves: their
``InferenceClientActor`` forwards ``select_action(observations)`` to ONE
``InferenceServer`` service node, which coalesces concurrent requests from
many workers into a single vmapped, jitted forward pass.  N actor processes
then cost one model dispatch per coalescing window instead of one per actor
per env step — the SEED-RL economics, on the Launchpad-lite graph.

Coalescing window semantics
---------------------------
A batcher thread collects requests under two bounds:

- ``max_batch_size``: total observation ROWS per forward pass (a vectorized
  actor's request contributes ``num_envs`` rows).  A request that would
  overflow the window waits for the next batch — requests are never split.
- ``max_wait_ms``: once the FIRST request of a window arrives, the batch is
  closed after at most this long even if not full.  A lone actor therefore
  pays at most ``max_wait_ms`` extra latency; a busy service fills batches
  before the deadline and the wait never triggers.

Observation batches are zero-padded up to the next power-of-two bucket
(≤ ``max_batch_size``) so XLA compiles a handful of shapes, not one per
distinct request mix; padded rows are dropped before replies fan back out.

The server owns the weights: a ``VariableClient`` on the learner is
refreshed once per ``update_period`` BATCHES (not per request), so weight
traffic scales with forward passes, not with actors.  ``stop()`` fails
pending and future callers with ``CourierClosed`` — a ConnectionError, which
launcher shutdown-noise classification already treats as benign once a stop
is in flight.

The coalescing machinery is factored into ``_BatchingServer`` so services
with richer request shapes (``repro.policies``' stateful KV-cache serving)
reuse the window/queue/shutdown semantics and only supply ``_execute``.
"""
from __future__ import annotations

import inspect
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.actors import STEP_MOD, _batched_policy
from repro.core.variable import VariableClient
from repro.telemetry import registry as _telemetry

# The RPC surface a Program node wrapping this server should declare.
INFERENCE_INTERFACE = ("select_action", "stats")


def policy_is_feed_forward(policy: Callable) -> bool:
    """True when ``policy`` has the (params, key, obs) arity the batched
    inference path can vmap; recurrent policies carry a 4th state argument
    the server would have to track per client (not supported)."""
    try:
        params = inspect.signature(policy).parameters
    except (TypeError, ValueError):
        return True   # builtins/jitted callables: assume feed-forward
    positional = [p for p in params.values()
                  if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    if any(p.kind == p.VAR_POSITIONAL for p in params.values()):
        return True
    return len(positional) == 3


class _Request:
    __slots__ = ("payload", "rows", "event", "result", "error", "t0")

    def __init__(self, payload: Any, rows: int):
        self.payload = payload
        self.rows = rows
        self.event = threading.Event()
        self.result: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None
        self.t0: Optional[float] = None   # submit time (telemetry only)


class _BatchingServer:
    """Request coalescing, the batcher thread, and shutdown plumbing.

    Subclasses call ``_submit(payload, rows)`` from their RPC methods and
    implement ``_execute(batch) -> (results, extra_stats)`` where
    ``results`` has one entry per request (assigned in order) and
    ``extra_stats`` maps stat names to increments merged under the lock.
    """

    def __init__(self, max_batch_size: int = 64, max_wait_ms: float = 2.0):
        if max_batch_size < 1:
            raise ValueError(f"max_batch_size must be >= 1, "
                             f"got {max_batch_size}")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        self._max_batch = int(max_batch_size)
        self._max_wait_s = float(max_wait_ms) / 1000.0

        self._cond = threading.Condition()
        self._pending: List[_Request] = []
        self._stopped = False
        self._stats: Dict[str, Any] = {"requests": 0, "rows": 0, "batches": 0}
        # Null (falsy) metrics when telemetry is off — the hot paths below
        # guard their clock reads on truthiness.
        self._m_queue_wait = _telemetry.histogram("inference/queue_wait_ms")
        self._m_batch_rows = _telemetry.histogram("inference/batch_rows")
        self._m_batch_occupancy = _telemetry.histogram(
            "inference/batch_occupancy")
        _telemetry.probe("inference/server", self.stats)
        self._thread = threading.Thread(target=self._batch_loop,
                                        name="inference_server",
                                        daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- RPC side
    def _submit(self, payload: Any, rows: int):
        """Enqueue one request and block until its rows come back from a
        coalesced forward pass.  Raises ``CourierClosed`` once stopped."""
        from repro.distributed.courier import CourierClosed

        if rows > self._max_batch:
            raise ValueError(
                f"request of {rows} rows exceeds max_batch_size="
                f"{self._max_batch}")
        request = _Request(payload, rows)
        if self._m_queue_wait:
            request.t0 = time.monotonic()
        with self._cond:
            if self._stopped:
                raise CourierClosed("inference server stopped")
            self._pending.append(request)
            self._cond.notify_all()
        request.event.wait()
        if request.error is not None:
            raise request.error
        return request.result

    def stats(self) -> Dict[str, Any]:
        with self._cond:
            s = dict(self._stats)
        s["avg_rows_per_batch"] = s["rows"] / max(s["batches"], 1)
        s["max_batch_size"] = self._max_batch
        s["max_wait_ms"] = self._max_wait_s * 1000.0
        return s

    def stop(self):
        with self._cond:
            self._stopped = True
            self._cond.notify_all()
        self._thread.join(timeout=5)

    # ------------------------------------------------------- batcher thread
    def _execute(self, batch: List[_Request]) -> Tuple[Sequence[Any],
                                                       Dict[str, Any]]:
        raise NotImplementedError

    def _collect(self) -> List[_Request]:
        """Block until a coalescing window closes; return its requests."""
        with self._cond:
            batch: List[_Request] = []
            rows = 0
            deadline = None
            while True:
                while (self._pending
                       and rows + self._pending[0].rows <= self._max_batch):
                    request = self._pending.pop(0)
                    batch.append(request)
                    rows += request.rows
                if self._stopped or rows >= self._max_batch:
                    return batch
                if not batch:
                    # idle: nothing to coalesce yet, no deadline running
                    self._cond.wait(0.1)
                    continue
                if self._pending:
                    return batch   # head request would overflow the window
                if deadline is None:
                    deadline = time.monotonic() + self._max_wait_s
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return batch
                self._cond.wait(remaining)

    def _run_batch(self, batch: List[_Request]):
        if self._m_queue_wait:
            now = time.monotonic()
            rows = 0
            for request in batch:
                rows += request.rows
                if request.t0 is not None:
                    self._m_queue_wait.observe((now - request.t0) * 1000.0)
            self._m_batch_rows.observe(rows)
            self._m_batch_occupancy.observe(rows / self._max_batch)
        try:
            results, extra = self._execute(batch)
            with self._cond:
                self._stats["batches"] += 1
                self._stats["requests"] += len(batch)
                self._stats["rows"] += sum(r.rows for r in batch)
                for k, v in extra.items():
                    self._stats[k] = self._stats.get(k, 0) + v
            for request, result in zip(batch, results):
                request.result = result
                request.event.set()
        except BaseException as e:   # noqa: BLE001 — forwarded to callers
            for request in batch:
                request.error = e
                request.event.set()

    def _fail_pending(self):
        from repro.distributed.courier import CourierClosed

        with self._cond:
            pending, self._pending = self._pending, []
        for request in pending:
            request.error = CourierClosed("inference server stopped")
            request.event.set()

    def _batch_loop(self):
        while True:
            batch = self._collect()
            if batch:
                self._run_batch(batch)
            with self._cond:
                if self._stopped:
                    break
        self._fail_pending()


class InferenceServer(_BatchingServer):
    """Coalesce ``select_action`` requests into one batched forward pass.

    ``policy`` is the per-example behaviour policy ``(params, key, obs) ->
    action`` every builder already provides; ``variable_source`` is anything
    with ``get_variables`` (the learner, or a handle to it).
    """

    def __init__(self, policy: Callable, variable_source,
                 max_batch_size: int = 64, max_wait_ms: float = 2.0,
                 update_period: int = 10, rng_seed: int = 0,
                 jit: bool = True):
        if not policy_is_feed_forward(policy):
            raise ValueError(
                "InferenceServer batches feed-forward policies "
                "(params, key, obs); recurrent policies would need per-client "
                "state tracking — use inference='local' for those agents")

        # the SAME key-derivation scheme the batched actors use (fold_in the
        # batch counter on device, split per-row keys, vmap)
        batched = _batched_policy(policy)
        self._policy = jax.jit(batched) if jit else batched
        self._client = VariableClient(variable_source,
                                      update_period=max(update_period, 1))
        self._key = jax.random.key(rng_seed)
        self._batch_counter = 0
        super().__init__(max_batch_size=max_batch_size,
                         max_wait_ms=max_wait_ms)
        with self._cond:
            self._stats.setdefault("padded_rows", 0)

    def select_action(self, observations) -> np.ndarray:
        """Batch in, batch out: ``(k, *obs_shape) -> (k, *action_shape)``.

        Blocks until this request's rows come back from a coalesced forward
        pass.  Raises ``CourierClosed`` once the server is stopped.
        """
        obs = np.asarray(observations)
        return self._submit(obs, obs.shape[0])

    def _execute(self, batch: List[_Request]):
        rows = sum(r.rows for r in batch)
        obs = np.concatenate([r.payload for r in batch], axis=0)
        # pad to a power-of-two bucket: a bounded set of compiled shapes
        bucket = 1
        while bucket < rows:
            bucket *= 2
        bucket = min(bucket, self._max_batch)
        if obs.shape[0] < bucket:
            pad = np.zeros((bucket - obs.shape[0],) + obs.shape[1:],
                           obs.dtype)
            obs = np.concatenate([obs, pad], axis=0)
        self._client.update()   # period counts BATCHES, not requests
        actions = np.asarray(self._policy(
            self._client.params, self._key, self._batch_counter, obs))
        self._batch_counter = (self._batch_counter + 1) % STEP_MOD
        results = []
        offset = 0
        for request in batch:
            results.append(actions[offset:offset + request.rows])
            offset += request.rows
        return results, {"padded_rows": bucket - rows}
