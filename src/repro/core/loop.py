"""The environment loop (Fig 2 of the paper, line-for-line) — and its
vectorized form, which drives N auto-resetting environments through a
batched actor with one policy dispatch per N transitions."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.interfaces import Actor
from repro.core.types import Environment


class Counter:
    """Shared step/episode counters (actor steps vs evaluator steps, §4.2)."""

    def __init__(self):
        import threading
        self._lock = threading.Lock()
        self._counts: Dict[str, float] = {}

    def increment(self, **deltas) -> Dict[str, float]:
        with self._lock:
            for k, v in deltas.items():
                self._counts[k] = self._counts.get(k, 0) + v
            return dict(self._counts)

    def get_counts(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._counts)

    def set_counts(self, counts: Dict[str, float]):
        """Replace all totals (exact resume: restored from a checkpoint)."""
        with self._lock:
            self._counts = dict(counts)

    # Recoverable-protocol aliases (repro.resilience.failover): the counter
    # service snapshots and restores like any other stateful service.
    def state_dict(self) -> Dict[str, float]:
        return self.get_counts()

    def load_state_dict(self, counts: Dict[str, float]):
        self.set_counts(counts)


class EnvironmentLoop:
    def __init__(self, environment: Environment, actor: Actor,
                 counter: Optional[Counter] = None,
                 logger: Optional[Callable[[Dict[str, Any]], None]] = None,
                 label: str = "environment_loop",
                 should_update: bool = True,
                 update_period: int = 1):
        if update_period < 1:
            raise ValueError(f"update_period must be >= 1, "
                             f"got {update_period}")
        self._environment = environment
        self._actor = actor
        self._counter = counter or Counter()
        self._logger = logger
        self._label = label
        self._should_update = should_update
        # actor.update() cadence in env steps: pure actors polling a remote
        # VariableClient need not be poked every single step (the client's
        # own update_period then applies to far fewer calls).  Synchronous
        # Agents keep the default of 1 — update() drives their learner.
        self._update_period = update_period
        self._update_calls = 0

    # -- exact resume (repro.resilience) -------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {"update_calls": self._update_calls}

    def load_state_dict(self, state: Dict[str, Any]):
        self._update_calls = int(state["update_calls"])

    def run_episode(self) -> Dict[str, Any]:
        episode_return = 0.0
        episode_steps = 0
        # monotonic: wall-clock adjustments must not yield negative rates
        start = time.monotonic()

        # Make an initial observation.
        step = self._environment.reset()
        self._actor.observe_first(step)

        while not step.last():
            # Evaluate the policy and take a step in the environment.
            action = self._actor.select_action(step.observation)
            step = self._environment.step(action)

            # Make an observation and update the actor.
            self._actor.observe(action, next_timestep=step)
            if self._should_update:
                self._update_calls += 1
                if self._update_calls % self._update_period == 0:
                    self._actor.update()

            episode_return += step.reward
            episode_steps += 1

        counts = self._counter.increment(
            **{f"{self._label}_episodes": 1,
               f"{self._label}_steps": episode_steps})
        result = {
            "episode_return": episode_return,
            "episode_length": episode_steps,
            "steps_per_second": episode_steps / max(
                time.monotonic() - start, 1e-9),
            **counts,
        }
        if self._logger:
            self._logger(result)
        return result

    def run(self, num_episodes: Optional[int] = None,
            num_steps: Optional[int] = None,
            should_stop: Optional[Callable[[], bool]] = None) -> List[Dict]:
        results = []
        steps = 0
        episodes = 0
        while True:
            if should_stop is not None and should_stop():
                break
            if num_episodes is not None and episodes >= num_episodes:
                break
            if num_steps is not None and steps >= num_steps:
                break
            result = self.run_episode()
            results.append(result)
            episodes += 1
            steps += result["episode_length"]
        return results


class VectorizedEnvironmentLoop:
    """The batched acting loop: N auto-resetting envs, one batched actor.

    Per tick the actor selects N actions in ONE vmapped policy dispatch and
    the ``VectorEnv`` advances every member env; per-env transitions are
    then routed to per-env adders (``observe(..., env_id=i)``), with an
    env's ``observe_first`` fired at its auto-reset boundary — so each env's
    experience stream is exactly what a single ``EnvironmentLoop`` would
    have produced.

    Counter/logging semantics match the single loop: a result dict per
    COMPLETED episode, ``{label}_episodes``/``{label}_steps`` incremented at
    episode ends, and only real transitions counted (an auto-reset tick is
    not a transition).  ``update_period`` is in ticks — one tick already
    covers N env steps.

    ``run`` is RESUMABLE: episodes in flight when a call's
    ``num_episodes``/``num_steps`` budget expires stay in flight — the next
    call continues them instead of resetting the envs (so chunked drivers
    like ``run_experiment``'s eval cadence never truncate per-env adder
    streams or discard partial episodes).  The budgets themselves are
    per-call, matching ``EnvironmentLoop.run``.
    """

    def __init__(self, vector_env, actor,
                 counter: Optional[Counter] = None,
                 logger: Optional[Callable[[Dict[str, Any]], None]] = None,
                 label: str = "environment_loop",
                 should_update: bool = True,
                 update_period: int = 1):
        if update_period < 1:
            raise ValueError(f"update_period must be >= 1, "
                             f"got {update_period}")
        self._environment = vector_env
        self._actor = actor
        self._counter = counter or Counter()
        self._logger = logger
        self._label = label
        self._should_update = should_update
        self._update_period = update_period
        # carried across run() calls (resume support)
        self._ts = None
        self._ep_return = [0.0] * vector_env.num_envs
        self._ep_steps = [0] * vector_env.num_envs
        # monotonic: wall-clock adjustments must not yield negative rates
        self._ep_start = [time.monotonic()] * vector_env.num_envs
        self._ticks = 0

    # -- exact resume (repro.resilience) -------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """Carried loop state: the tick counter (weight-sync cadence) and
        the per-env in-flight episode accumulators.  The batched timestep
        itself is NOT captured — the envs restore through ``VectorEnv.
        get_state``/``set_state`` and the next ``run()`` call re-derives
        the observation from them."""
        return {"ticks": self._ticks,
                "ep_return": list(self._ep_return),
                "ep_steps": list(self._ep_steps)}

    def load_state_dict(self, state: Dict[str, Any]):
        self._ticks = int(state["ticks"])
        self._ep_return = [float(r) for r in state["ep_return"]]
        self._ep_steps = [int(s) for s in state["ep_steps"]]

    def run(self, num_episodes: Optional[int] = None,
            num_steps: Optional[int] = None,
            should_stop: Optional[Callable[[], bool]] = None) -> List[Dict]:
        from repro.envs.vector import split_timestep

        num_envs = self._environment.num_envs
        results: List[Dict] = []
        call_steps = 0

        if self._ts is None:   # first call only; later calls resume
            self._ts = self._environment.reset()
            now = time.monotonic()
            for i in range(num_envs):
                self._actor.observe_first(split_timestep(self._ts, i),
                                          env_id=i)
                self._ep_start[i] = now

        while True:
            if should_stop is not None and should_stop():
                break
            if num_episodes is not None and len(results) >= num_episodes:
                break
            if num_steps is not None and call_steps >= num_steps:
                break

            # ONE batched policy dispatch for all N envs.
            actions = self._actor.select_action(self._ts.observation)
            self._ts = self._environment.step(actions)

            for i in range(num_envs):
                ts_i = split_timestep(self._ts, i)
                if ts_i.first():
                    # auto-reset boundary: a fresh episode starts for env i
                    self._actor.observe_first(ts_i, env_id=i)
                    self._ep_return[i], self._ep_steps[i] = 0.0, 0
                    self._ep_start[i] = time.monotonic()
                    continue
                self._actor.observe(actions[i], ts_i, env_id=i)
                self._ep_return[i] += ts_i.reward
                self._ep_steps[i] += 1
                call_steps += 1
                if ts_i.last():
                    counts = self._counter.increment(
                        **{f"{self._label}_episodes": 1,
                           f"{self._label}_steps": self._ep_steps[i]})
                    result = {
                        "episode_return": self._ep_return[i],
                        "episode_length": self._ep_steps[i],
                        "steps_per_second": self._ep_steps[i] / max(
                            time.monotonic() - self._ep_start[i], 1e-9),
                        "env_id": i,
                        **counts,
                    }
                    results.append(result)
                    if self._logger:
                        self._logger(result)

            self._ticks += 1
            if self._should_update and self._ticks % self._update_period == 0:
                self._actor.update()
        return results
