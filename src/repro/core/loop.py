"""The environment loop (Fig 2 of the paper, line-for-line)."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

from repro.core.interfaces import Actor
from repro.core.types import Environment


class Counter:
    """Shared step/episode counters (actor steps vs evaluator steps, §4.2)."""

    def __init__(self):
        import threading
        self._lock = threading.Lock()
        self._counts: Dict[str, float] = {}

    def increment(self, **deltas) -> Dict[str, float]:
        with self._lock:
            for k, v in deltas.items():
                self._counts[k] = self._counts.get(k, 0) + v
            return dict(self._counts)

    def get_counts(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._counts)


class EnvironmentLoop:
    def __init__(self, environment: Environment, actor: Actor,
                 counter: Optional[Counter] = None,
                 logger: Optional[Callable[[Dict[str, Any]], None]] = None,
                 label: str = "environment_loop",
                 should_update: bool = True):
        self._environment = environment
        self._actor = actor
        self._counter = counter or Counter()
        self._logger = logger
        self._label = label
        self._should_update = should_update

    def run_episode(self) -> Dict[str, Any]:
        episode_return = 0.0
        episode_steps = 0
        start = time.time()

        # Make an initial observation.
        step = self._environment.reset()
        self._actor.observe_first(step)

        while not step.last():
            # Evaluate the policy and take a step in the environment.
            action = self._actor.select_action(step.observation)
            step = self._environment.step(action)

            # Make an observation and update the actor.
            self._actor.observe(action, next_timestep=step)
            if self._should_update:
                self._actor.update()

            episode_return += step.reward
            episode_steps += 1

        counts = self._counter.increment(
            **{f"{self._label}_episodes": 1,
               f"{self._label}_steps": episode_steps})
        result = {
            "episode_return": episode_return,
            "episode_length": episode_steps,
            "steps_per_second": episode_steps / max(time.time() - start, 1e-9),
            **counts,
        }
        if self._logger:
            self._logger(result)
        return result

    def run(self, num_episodes: Optional[int] = None,
            num_steps: Optional[int] = None,
            should_stop: Optional[Callable[[], bool]] = None) -> List[Dict]:
        results = []
        steps = 0
        episodes = 0
        while True:
            if should_stop is not None and should_stop():
                break
            if num_episodes is not None and episodes >= num_episodes:
                break
            if num_steps is not None and steps >= num_steps:
                break
            result = self.run_episode()
            results.append(result)
            episodes += 1
            steps += result["episode_length"]
        return results
