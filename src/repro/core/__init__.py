"""Acme's core: actors, learners, agents, environment loops, variable flow."""
from repro.builders import AgentBuilder, BuilderOptions  # noqa: F401
from repro.core.actors import (  # noqa: F401
    BatchedFeedForwardActor, BatchedRecurrentActor, FeedForwardActor,
    InferenceClientActor, RecurrentActor)
from repro.core.agent import Agent  # noqa: F401
from repro.core.inference import INFERENCE_INTERFACE, InferenceServer  # noqa: F401
from repro.core.interfaces import Actor, Learner, VariableSource, Worker  # noqa: F401
from repro.core.loop import (  # noqa: F401
    Counter, EnvironmentLoop, VectorizedEnvironmentLoop)
from repro.core.types import (  # noqa: F401
    ArraySpec, BoundedArraySpec, DiscreteArraySpec, Environment,
    EnvironmentSpec, StepType, TimeStep, Transition, make_environment_spec,
    restart, termination, transition, truncation)
from repro.core.variable import VariableClient, VariableServer  # noqa: F401
