"""dm_env-style core types (the container has no dm_env, so we provide the
same interface surface Acme assumes: TimeStep/StepType + Environment + specs).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, NamedTuple, Optional, Tuple

import numpy as np


class StepType(enum.IntEnum):
    FIRST = 0
    MID = 1
    LAST = 2


class TimeStep(NamedTuple):
    step_type: StepType
    reward: Optional[float]
    discount: Optional[float]
    observation: Any

    def first(self) -> bool:
        return self.step_type == StepType.FIRST

    def mid(self) -> bool:
        return self.step_type == StepType.MID

    def last(self) -> bool:
        return self.step_type == StepType.LAST


def restart(observation) -> TimeStep:
    return TimeStep(StepType.FIRST, None, None, observation)


def transition(reward, observation, discount=1.0) -> TimeStep:
    return TimeStep(StepType.MID, reward, discount, observation)


def termination(reward, observation) -> TimeStep:
    return TimeStep(StepType.LAST, reward, 0.0, observation)


def truncation(reward, observation, discount=1.0) -> TimeStep:
    return TimeStep(StepType.LAST, reward, discount, observation)


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    shape: Tuple[int, ...]
    dtype: Any
    name: str = ""

    def validate(self, value):
        value = np.asarray(value)
        if tuple(value.shape) != tuple(self.shape):
            raise ValueError(f"{self.name}: shape {value.shape} != {self.shape}")
        return value

    def generate_value(self):
        return np.zeros(self.shape, self.dtype)


@dataclasses.dataclass(frozen=True)
class BoundedArraySpec(ArraySpec):
    minimum: float = -np.inf
    maximum: float = np.inf


@dataclasses.dataclass(frozen=True)
class DiscreteArraySpec(ArraySpec):
    num_values: int = 0

    def __post_init__(self):
        object.__setattr__(self, "shape", ())
        object.__setattr__(self, "dtype", np.int32)


@dataclasses.dataclass(frozen=True)
class EnvironmentSpec:
    observations: Any
    actions: Any
    rewards: ArraySpec
    discounts: ArraySpec


class Environment:
    """dm_env.Environment interface."""

    def reset(self) -> TimeStep:
        raise NotImplementedError

    def step(self, action) -> TimeStep:
        raise NotImplementedError

    def observation_spec(self):
        raise NotImplementedError

    def action_spec(self):
        raise NotImplementedError

    def reward_spec(self) -> ArraySpec:
        return ArraySpec((), np.float32, "reward")

    def discount_spec(self) -> ArraySpec:
        return BoundedArraySpec((), np.float32, "discount", 0.0, 1.0)

    def close(self):
        pass


def make_environment_spec(env: Environment) -> EnvironmentSpec:
    return EnvironmentSpec(
        observations=env.observation_spec(),
        actions=env.action_spec(),
        rewards=env.reward_spec(),
        discounts=env.discount_spec(),
    )


class Transition(NamedTuple):
    """(o_t, a_t, r_t, d_t, o_{t+1}) — with n-step aggregates when adder says."""
    observation: Any
    action: Any
    reward: Any
    discount: Any
    next_observation: Any
    extras: Any = ()
