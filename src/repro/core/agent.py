"""The synchronous learning agent (§2.2): an actor that owns a learner and
triggers learner steps from update(), governed by a local
min_observations / observations_per_step schedule (the single-process
equivalent of the rate limiter's SPI)."""
from __future__ import annotations

from typing import Optional

from repro.core.interfaces import Actor, Learner
from repro.core.types import TimeStep


class Agent(Actor):
    def __init__(self, actor: Actor, learner: Learner,
                 min_observations: int, observations_per_step: float,
                 can_step=None):
        self._actor = actor
        self._learner = learner
        self._min_observations = min_observations
        self._observations_per_step = observations_per_step
        self._num_observations = 0
        self._learner_steps_taken = 0
        # synchronous-safety guard: don't call a learner step that would
        # block on the dataset (queue not yet holding a full batch).
        self._can_step = can_step

    def select_action(self, observation):
        return self._actor.select_action(observation)

    def observe_first(self, timestep: TimeStep, **kwargs):
        self._actor.observe_first(timestep, **kwargs)

    def observe(self, action, next_timestep: TimeStep, **kwargs):
        self._num_observations += 1
        self._actor.observe(action, next_timestep, **kwargs)

    def update(self, wait: bool = False):
        # Step the learner up to the schedule's target for the observations
        # seen so far.  Target-based (rather than fire-on-modulo) so one
        # update() after a BATCH of observations — the vectorized loop calls
        # update once per N-env tick — runs the same number of learner steps
        # as N per-observation updates would have.
        n = self._num_observations - self._min_observations
        if n < 0:
            return
        if self._observations_per_step >= 1:
            target = n // int(self._observations_per_step) + 1
        else:
            target = (n + 1) * int(1 / self._observations_per_step)
        stepped = 0
        while self._learner_steps_taken < target:
            if self._can_step is not None and not self._can_step():
                break
            self._learner.step()
            self._learner_steps_taken += 1
            stepped += 1
        if stepped:
            self._actor.update()

    @property
    def learner(self) -> Learner:
        return self._learner

    @property
    def actor(self) -> Actor:
        return self._actor

    # -- exact resume (repro.resilience) -------------------------------
    def state_dict(self):
        # The observation/step counters drive the target-based learner
        # schedule: restoring them keeps post-resume learner steps on
        # exactly the same observations as the uninterrupted run.
        return {"num_observations": self._num_observations,
                "learner_steps_taken": self._learner_steps_taken,
                "actor": self._actor.state_dict()}

    def load_state_dict(self, state):
        self._num_observations = int(state["num_observations"])
        self._learner_steps_taken = int(state["learner_steps_taken"])
        self._actor.load_state_dict(state["actor"])
