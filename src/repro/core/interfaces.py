"""Acme's core abstractions: Actor, Learner, VariableSource (§2 of the paper)."""
from __future__ import annotations

import abc
from typing import Any, Dict, List, Sequence

from repro.core.types import TimeStep


class VariableSource(abc.ABC):
    """Anything that can hand out named collections of variables (a learner)."""

    @abc.abstractmethod
    def get_variables(self, names: Sequence[str] = ()) -> List[Any]:
        ...


class Actor(abc.ABC):
    """Interacts with the environment: Fig 2's select_action/observe/update."""

    @abc.abstractmethod
    def select_action(self, observation) -> Any:
        ...

    @abc.abstractmethod
    def observe_first(self, timestep: TimeStep):
        ...

    @abc.abstractmethod
    def observe(self, action, next_timestep: TimeStep):
        ...

    @abc.abstractmethod
    def update(self, wait: bool = False):
        """Pull fresh weights / trigger learner steps (agents)."""
        ...

    # -- exact resume (repro.resilience) -------------------------------
    # Actors carry only small host-side state (RNG step counters); the
    # default is stateless.  Overrides must round-trip everything that
    # influences future action draws, captured at an episode boundary.
    def state_dict(self) -> Dict[str, Any]:
        return {}

    def load_state_dict(self, state: Dict[str, Any]):
        pass


class Learner(VariableSource, abc.ABC):
    """Consumes batches, runs SGD (§2.2)."""

    @abc.abstractmethod
    def step(self) -> Dict[str, Any]:
        """One learner step; returns metrics."""
        ...

    def run(self, num_steps: int) -> Dict[str, Any]:
        metrics = {}
        for _ in range(num_steps):
            metrics = self.step()
        return metrics


class Worker(abc.ABC):
    """A runnable node in a distributed program (Launchpad-lite)."""

    @abc.abstractmethod
    def run(self):
        ...
