"""Minimal optax-style optimizers (no optax in the container).

An :class:`Optimizer` is a pair of pure functions ``init(params) -> state``
and ``update(grads, state, params) -> (updates, state)``; ``apply_updates``
adds updates to params.  Includes Adam(W), SGD+momentum, global-norm
clipping, LR schedules, and the paper's target-network update helpers
(periodic copy for DQN-family, EMA for MPO-family).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

OptState = Any
Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple]


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def _to_schedule(lr: Union[float, Schedule]) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam(lr: Union[float, Schedule], b1=0.9, b2=0.999, eps=1e-8,
         weight_decay: float = 0.0, clip: Optional[float] = None) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(zeros, params), jax.tree.map(zeros, params))

    def update(grads, state: AdamState, params=None):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if clip is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** step), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** step), nu)
        lr_t = sched(step)
        updates = jax.tree.map(
            lambda m, v: -lr_t * m / (jnp.sqrt(v) + eps), mu_hat, nu_hat)
        if weight_decay and params is not None:
            updates = jax.tree.map(
                lambda u, p: u - lr_t * weight_decay * p.astype(jnp.float32),
                updates, params)
        return updates, AdamState(step, mu, nu)

    return Optimizer(init, update)


class SgdState(NamedTuple):
    step: jax.Array
    momentum: Any


def sgd(lr: Union[float, Schedule], momentum: float = 0.0,
        clip: Optional[float] = None) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        return SgdState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(grads, state: SgdState, params=None):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if clip is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, clip / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        mom = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
        lr_t = sched(step)
        return jax.tree.map(lambda m: -lr_t * m, mom), SgdState(step, mom)

    return Optimizer(init, update)


def chain_clip(opt: Optimizer, max_norm: float) -> Optimizer:
    """Prepend global-norm clipping to any optimizer."""
    def update(grads, state, params=None):
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        return opt.update(grads, state, params)
    return Optimizer(opt.init, update)


def linear_warmup(base: float, warmup_steps: int) -> Schedule:
    def sched(step):
        return base * jnp.minimum(1.0, step / max(warmup_steps, 1))
    return sched


def cosine_schedule(base: float, total_steps: int, warmup_steps: int = 0,
                    final_frac: float = 0.1) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / max(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base * jnp.where(step < warmup_steps, warm, cos)
    return sched


# ------------------------------------------------------- target networks
def periodic_update(online, target, step, period: int):
    """DQN-style: copy online -> target every ``period`` steps."""
    copy = (step % period) == 0
    return jax.tree.map(lambda o, t: jnp.where(copy, o, t), online, target)


def incremental_update(online, target, tau: float):
    """EMA target (MPO/DDPG-style soft update)."""
    return jax.tree.map(lambda o, t: tau * o + (1 - tau) * t, online, target)
