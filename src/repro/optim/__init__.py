from repro.optim.optimizers import (  # noqa: F401
    OptState, adam, sgd, chain_clip, Optimizer,
    apply_updates, global_norm, cosine_schedule, linear_warmup,
    periodic_update, incremental_update,
)
