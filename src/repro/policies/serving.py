"""The transformer-policy inference service.

A ``_BatchingServer`` (the generic coalescing window / queue / shutdown
machinery from ``repro.core.inference``) whose execute step is a
``PolicyEngine`` pass: requests carry observation WINDOWS plus episode
steps, the engine routes each row to batched prefill or incremental
KV-cache decode against its per-episode cache slot, and one jitted forward
pass (optionally on the pallas ``decode_attention`` kernel) answers the
whole coalesced batch.

Weights live in a ``VariableClient`` on the learner, refreshed once per
``update_period`` batches; a refresh invalidates every live cache slot
(stale-cache rejection), so the next pass re-prefills rather than mixing
old K/V with new queries.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.inference import _BatchingServer, _Request
from repro.core.variable import VariableClient


class TransformerInferenceServer(_BatchingServer):
    """Coalesce windowed ``select_action`` requests into engine passes."""

    INTERFACE = ("select_action", "window", "release", "stats")

    def __init__(self, engine, variable_source, max_batch_size: int = 64,
                 max_wait_ms: float = 2.0, update_period: int = 10):
        self._engine = engine
        self._client = VariableClient(variable_source,
                                      update_period=max(update_period, 1))
        super().__init__(max_batch_size=max_batch_size,
                         max_wait_ms=max_wait_ms)

    # ------------------------------------------------------------- RPC side
    def select_action(self, windows, positions, client_id) -> np.ndarray:
        """windows: (k, W, *obs_shape) left-aligned; positions: (k,) episode
        steps of each row's newest frame; ``client_id`` namespaces the
        cache-slot keys (row i -> key ``(client_id, i)``)."""
        windows = np.asarray(windows, np.float32)
        positions = np.asarray(positions, np.int64)
        return self._submit((windows, positions, client_id),
                            windows.shape[0])

    def window(self) -> int:
        """The policy's observation-window length (clients size buffers)."""
        return int(self._engine.window)

    def release(self, client_id):
        """Free every cache slot held for ``client_id`` (disconnect)."""
        self._engine.release_client(client_id)

    def stats(self):
        s = super().stats()
        s.update(self._engine.stats())
        return s

    # ------------------------------------------------------- batcher thread
    def _execute(self, batch: List[_Request]):
        windows = np.concatenate([r.payload[0] for r in batch], axis=0)
        positions = np.concatenate([r.payload[1] for r in batch], axis=0)
        keys = []
        for request in batch:
            client_id = request.payload[2]
            keys.extend((client_id, i) for i in range(request.rows))
        self._client.update()   # period counts BATCHES, not requests
        actions = self._engine.select(self._client.params, keys, windows,
                                      positions)
        results = []
        offset = 0
        for request in batch:
            results.append(actions[offset:offset + request.rows])
            offset += request.rows
        return results, {}
