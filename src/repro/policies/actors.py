"""Actors that feed observation windows to a transformer policy.

All three actors keep the same tiny piece of host state per environment —
a ``_WindowBuffer`` holding the last W observations left-aligned — and
differ only in where the forward pass runs:

- ``WindowedPolicyActor``: single env, local ``PolicyEngine`` (one cache
  slot) — incremental KV-cache decode without any server.
- ``BatchedWindowedPolicyActor``: N envs through one engine call per tick
  (the vectorized-acting contract of ``BatchedFeedForwardActor``).
- ``WindowedInferenceClientActor``: SEED-style client; windows go over RPC
  to a ``TransformerInferenceServer`` which owns weights, caches, and the
  pallas decode kernel.

Cache-slot keys are stable per environment; episode ends need no RPC —
the engine sees the position drop back to 0 (≠ ``slot.pos + 1``) and
recycles the slot in place via the prefill path.
"""
from __future__ import annotations

import uuid
from typing import Optional, Sequence

import numpy as np

from repro.core.interfaces import Actor
from repro.core.types import TimeStep


class _WindowBuffer:
    """Last-W-observations buffer, materialized LEFT-aligned (oldest first,
    zero-padded on the right) — the layout ``PolicyEngine.select`` and the
    learner's replayed sequences share."""

    def __init__(self, window: int, obs_shape):
        self.window = window
        self.obs_shape = tuple(obs_shape)
        self.frames = []
        self.t = -1               # episode step of the newest frame

    def reset(self):
        self.frames = []
        self.t = -1

    def push(self, observation):
        self.frames.append(np.asarray(observation, np.float32))
        if len(self.frames) > self.window:
            self.frames.pop(0)
        self.t += 1

    def window_array(self) -> np.ndarray:
        out = np.zeros((self.window,) + self.obs_shape, np.float32)
        for i, f in enumerate(self.frames):
            out[i] = f
        return out


class WindowedPolicyActor(Actor):
    """Single-env local acting through a one-slot ``PolicyEngine``: the
    same incremental-decode hot path as the server, minus the RPC."""

    def __init__(self, engine, variable_client, adder=None):
        self._engine = engine
        self._client = variable_client
        self._adder = adder
        self._buffer = _WindowBuffer(engine.window, engine.obs_shape)

    def select_action(self, observation):
        self._buffer.push(observation)
        actions = self._engine.select(
            self._client.params, ["env0"],
            self._buffer.window_array()[None], [self._buffer.t])
        return actions[0]

    def observe_first(self, timestep: TimeStep):
        self._buffer.reset()
        if self._adder:
            self._adder.add_first(timestep)

    def observe(self, action, next_timestep: TimeStep):
        if self._adder:
            self._adder.add(action, next_timestep)

    def update(self, wait: bool = False):
        self._client.update(wait)


class BatchedWindowedPolicyActor(Actor):
    """N envs, one ``PolicyEngine.select`` per tick (vectorized acting)."""

    def __init__(self, engine, variable_client, adders):
        self._engine = engine
        self._client = variable_client
        self._adders = list(adders)
        self._buffers = [_WindowBuffer(engine.window, engine.obs_shape)
                         for _ in range(len(self._adders))]

    def _adder(self, env_id: int):
        return self._adders[env_id] if env_id < len(self._adders) else None

    def select_action(self, observation):
        obs = np.asarray(observation)
        keys, windows, positions = [], [], []
        for i in range(obs.shape[0]):
            self._buffers[i].push(obs[i])
            keys.append(f"env{i}")
            windows.append(self._buffers[i].window_array())
            positions.append(self._buffers[i].t)
        return self._engine.select(self._client.params, keys,
                                   np.stack(windows), positions)

    def observe_first(self, timestep: TimeStep, env_id: int = 0):
        self._buffers[env_id].reset()
        adder = self._adder(env_id)
        if adder:
            adder.add_first(timestep)

    def observe(self, action, next_timestep: TimeStep, env_id: int = 0):
        adder = self._adder(env_id)
        if adder:
            adder.add(action, next_timestep)

    def update(self, wait: bool = False):
        self._client.update(wait)


class WindowedInferenceClientActor(Actor):
    """SEED-style client for ``TransformerInferenceServer``: windows and
    episode steps go over ``select_action(windows, positions, client_id)``;
    the server's engine keys cache slots by ``(client_id, env_id)``, so the
    whole slot lifecycle lives server-side.  ``update`` is a no-op — the
    server owns the weights."""

    def __init__(self, inference, adder=None, adders=None,
                 batched: bool = False):
        if adder is not None and adders is not None:
            raise ValueError("pass either adder= or adders=, not both")
        self._inference = inference
        self._adders = list(adders) if adders is not None \
            else ([adder] if adder is not None else [])
        self._batched = batched
        self._client_id = uuid.uuid4().hex
        self._buffers: Optional[Sequence[_WindowBuffer]] = None

    def _adder(self, env_id: int):
        return self._adders[env_id] if env_id < len(self._adders) else None

    def _ensure_buffers(self, obs_shape, num_envs: int):
        if self._buffers is None:
            window = int(self._inference.window())
            self._buffers = [_WindowBuffer(window, obs_shape)
                             for _ in range(num_envs)]

    def select_action(self, observation):
        obs = np.asarray(observation, np.float32)
        if not self._batched:
            obs = obs[None]
        self._ensure_buffers(obs.shape[1:], obs.shape[0])
        windows, positions = [], []
        for i in range(obs.shape[0]):
            self._buffers[i].push(obs[i])
            windows.append(self._buffers[i].window_array())
            positions.append(self._buffers[i].t)
        actions = np.asarray(self._inference.select_action(
            np.stack(windows), np.asarray(positions, np.int64),
            self._client_id))
        return actions if self._batched else actions[0]

    def observe_first(self, timestep: TimeStep, env_id: int = 0):
        if self._buffers is not None:
            self._buffers[env_id].reset()
        adder = self._adder(env_id)
        if adder:
            adder.add_first(timestep)

    def observe(self, action, next_timestep: TimeStep, env_id: int = 0):
        adder = self._adder(env_id)
        if adder:
            adder.add(action, next_timestep)

    def update(self, wait: bool = False):
        pass   # the TransformerInferenceServer owns the weights
