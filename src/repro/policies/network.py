"""The transformer Q-network: observations in, Q-values out.

Three views of ONE parameter set, all running the same
``repro.models.transformer`` dense stack:

- ``q_sequence``: full-sequence recompute over (B, T) observation windows —
  the learner's forward pass and the parity oracle for the decode paths.
- ``q_prefill``: batched prompt prefill THROUGH the KV cache (one call for
  a whole window, right-padded rows masked via ``lengths``).
- ``q_decode``: one-token incremental decode against the cache with
  per-row positions — the serving hot path, optionally on the pallas
  ``decode_attention`` kernel.

Observations are embedded by a learned linear projection (``obs_proj``)
instead of a token table, and Q-values come from a linear ``head`` instead
of the unembedding — the ``*_embedded`` transformer entry points exist for
exactly this.  ``sliding_window = window`` makes train-time attention
banded, so the learner over length-T sequences and the actor over length-W
windows compute the SAME function (RoPE is relative, so window-local
positions are equivalent to absolute ones).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, transformer
from repro.models.config import ArchConfig


def make_arch(cfg, num_actions: int) -> ArchConfig:
    """The ``ArchConfig`` for a policy; ``cfg`` is a TransformerPolicyConfig."""
    return ArchConfig(
        name="transformer_policy", arch_type="dense",
        num_layers=cfg.num_layers, d_model=cfg.d_model,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        d_ff=cfg.d_ff, vocab_size=max(num_actions, 1),
        head_dim=cfg.head_dim, rope_theta=10_000.0,
        sliding_window=cfg.window, tie_embeddings=True,
        source="repro.policies")


def init(key, arch: ArchConfig, obs_dim: int, num_actions: int,
         dtype=jnp.float32):
    kp, kb, kh = jax.random.split(key, 3)
    return {
        "obs_proj": {
            "w": layers.dense_init(kp, obs_dim, arch.d_model, dtype),
            "b": jnp.zeros((arch.d_model,), dtype),
        },
        "blocks": transformer._stack_init(
            kb, arch.num_layers,
            lambda k: transformer._dense_block_init(k, arch, dtype)),
        "final_norm": layers.rmsnorm_init(arch.d_model, dtype),
        "head": layers.dense_init(kh, arch.d_model, num_actions, dtype),
    }


def embed_obs(params, obs):
    """(..., obs_dim) float32 -> (..., d_model)."""
    p = params["obs_proj"]
    return jnp.einsum("...i,id->...d", obs, p["w"]) + p["b"]


def _q_head(params, feats):
    return jnp.einsum("...d,da->...a", feats, params["head"])


def q_sequence(params, arch: ArchConfig, obs):
    """Full-sequence Q-values: obs (B, T, obs_dim) -> (B, T, A)."""
    x = embed_obs(params, obs)
    feats, _ = transformer.forward_embedded(
        {"blocks": params["blocks"], "final_norm": params["final_norm"]},
        arch, x)
    return _q_head(params, feats)


def init_cache(arch: ArchConfig, batch: int):
    """Decode caches sized to the policy window (the ring length)."""
    return transformer.init_cache(arch, batch, arch.sliding_window,
                                  jnp.float32)


def q_prefill(params, arch: ArchConfig, cache, obs, lengths):
    """Batched window prefill through the cache.

    obs (b, W, obs_dim) LEFT-aligned, zero-padded on the right; lengths (b,)
    real window lengths.  Returns ((b, W, A), new_cache) — decode continues
    at per-row position ``lengths[i]``.
    """
    x = embed_obs(params, obs)
    feats, cache = transformer.prefill_embedded(
        {"blocks": params["blocks"], "final_norm": params["final_norm"]},
        arch, cache, x, lengths=lengths)
    return _q_head(params, feats), cache


def q_decode(params, arch: ArchConfig, cache, obs, pos, *,
             backend: str = "jnp"):
    """One-observation incremental decode: obs (b, obs_dim), pos (b,) int32
    true episode-step positions.  Returns ((b, A), new_cache)."""
    x = embed_obs(params, obs)[:, None, :]
    feats, cache = transformer.decode_step_embedded(
        {"blocks": params["blocks"], "final_norm": params["final_norm"]},
        arch, cache, x, pos, backend=backend)
    return _q_head(params, feats), cache
