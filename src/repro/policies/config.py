"""Configuration for the transformer policy subsystem."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class TransformerPolicyConfig:
    """Knobs for ``TransformerPolicyBuilder``.

    Architecture: a small dense transformer over a sliding window of the
    last ``window`` observations, each projected to a ``d_model`` token.
    Serving: ``cache_slots`` bounds concurrent episodes holding a KV-cache
    slot on the inference server; ``backend`` picks the decode-attention
    path (``"auto"`` = pallas ``decode_attention`` kernel on TPU, the
    ``kernels/ref.py`` oracle elsewhere; ``"jnp"``/``"kernel"``/``"ref"``
    force one).  Learning: R2D2-style sequence double-DQN over replayed
    windows.
    """

    # architecture
    num_layers: int = 2
    d_model: int = 64
    num_heads: int = 4
    num_kv_heads: int = 2
    head_dim: int = 32
    d_ff: int = 128
    window: int = 8                  # observations the policy attends over

    # acting / serving
    epsilon: float = 0.1
    cache_slots: int = 64            # concurrent episodes on the server
    slot_timeout_s: float = 5.0      # acquire() backpressure bound
    backend: str = "auto"            # decode-attention path

    # learning (sequence double-DQN, R2D2-style schedule)
    learning_rate: float = 1e-3
    discount: float = 0.99
    sequence_length: int = 16
    period: int = 8                  # overlapping sequences
    batch_size: int = 16
    target_update_period: int = 100
    min_replay_size: int = 100
    max_replay_size: int = 20_000
    samples_per_insert: float = 4.0
    priority_eta: float = 0.9        # max/mean TD mixing
    importance_beta: float = 0.6
