"""Transformer policies on the serving fast path (§3.2 agents × §4 serving).

``repro.policies`` puts ``repro.models.transformer`` on the RL acting hot
path: a sliding window of observations is the policy's token sequence,
acting runs incremental KV-cache decode (optionally on the pallas
``decode_attention`` kernel), and ``inference="server"`` programs serve
every actor from one continuous-batching ``TransformerInferenceServer``
with per-episode cache slots.
"""
from repro.policies.builder import (TransformerPolicy,
                                    TransformerPolicyBuilder)
from repro.policies.cache import CacheSlotsExhausted, KVCachePool
from repro.policies.config import TransformerPolicyConfig
from repro.policies.engine import PolicyEngine
from repro.policies.serving import TransformerInferenceServer

__all__ = [
    "CacheSlotsExhausted",
    "KVCachePool",
    "PolicyEngine",
    "TransformerInferenceServer",
    "TransformerPolicy",
    "TransformerPolicyBuilder",
    "TransformerPolicyConfig",
]
