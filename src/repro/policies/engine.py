"""The policy-serving engine: routing between prefill and incremental decode.

``select`` takes one batch of (episode key, observation window, episode
step) rows — from any mix of clients — and answers every row with an
eps-greedy action while keeping each episode's KV-cache slot current:

- a row whose slot is CURRENT (same weights generation, step exactly one
  past the slot's last step) takes the DECODE path: one token through the
  cache, optionally on the pallas ``decode_attention`` kernel;
- every other row (new episode, episode restart, dropped step, or weights
  refreshed since the slot was filled) takes the PREFILL path: its whole
  window is pushed through the cache in one batched call.

Both paths gather the group's slot rows from the pool's batched cache, run
ONE jitted call padded to a power-of-two bucket (pad rows ride the pool's
scratch slot), and scatter the updated rows back — continuous batching over
per-episode cache state.

Weight refresh detection is object identity on ``params`` (a
``VariableClient`` only rebinds ``.params`` when it actually fetched new
weights): a refresh bumps the pool generation, so every live slot
re-prefills before its next decode rather than mixing stale K/V into fresh
queries.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.actors import STEP_MOD
from repro.models.config import ArchConfig
from repro.policies import network
from repro.policies.cache import KVCachePool
from repro.telemetry import registry as _telemetry


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class PolicyEngine:
    """Stateful transformer-policy evaluation over a ``KVCachePool``."""

    def __init__(self, arch: ArchConfig, obs_shape, num_actions: int, *,
                 num_slots: int, epsilon: float = 0.0,
                 backend: str = "auto", slot_timeout_s: float = 5.0,
                 rng_seed: int = 0, jit: bool = True):
        self.arch = arch
        self.window = arch.sliding_window
        self.obs_shape = tuple(obs_shape)
        self.obs_dim = int(np.prod(obs_shape)) or 1
        self.num_actions = num_actions
        self.epsilon = float(epsilon)
        self.pool = KVCachePool(arch, num_slots, timeout_s=slot_timeout_s)
        self._key = jax.random.key(rng_seed)
        self._step = 0
        self._last_params = None
        self._stats = {"prefill_rows": 0, "decode_rows": 0,
                       "prefill_batches": 0, "decode_batches": 0,
                       "cache_invalidations": 0, "stale_reprefills": 0}
        # Exported as gauges at snapshot time (no-op when telemetry is off);
        # covers slot utilization, prefill/decode ratio, re-prefill counts.
        _telemetry.probe("inference/engine", self.stats)

        eps = self.epsilon

        def eps_greedy(q, key, step, rows):
            key = jax.random.fold_in(key, step)
            keys = jax.random.split(key, rows)
            greedy = jnp.argmax(q, axis=-1).astype(jnp.int32)
            rand = jax.vmap(lambda k: jax.random.randint(
                k, (), 0, num_actions))(keys).astype(jnp.int32)
            explore = jax.vmap(lambda k: jax.random.uniform(k) < eps)(keys)
            return jnp.where(explore, rand, greedy)

        def prefill_fn(params, sub_cache, windows, lengths, key, step):
            obs = windows.reshape(windows.shape[0], windows.shape[1], -1)
            q, sub_cache = network.q_prefill(params, arch, sub_cache, obs,
                                             lengths)
            rows = jnp.arange(q.shape[0])
            q_last = q[rows, jnp.maximum(lengths - 1, 0)]
            return eps_greedy(q_last, key, step, q.shape[0]), sub_cache

        def decode_fn(params, sub_cache, obs, pos, key, step):
            obs = obs.reshape(obs.shape[0], -1)
            q, sub_cache = network.q_decode(params, arch, sub_cache, obs,
                                            pos, backend=backend)
            return eps_greedy(q, key, step, q.shape[0]), sub_cache

        self._prefill = jax.jit(prefill_fn) if jit else prefill_fn
        self._decode = jax.jit(decode_fn) if jit else decode_fn

    # ----------------------------------------------------------- the hot path
    def select(self, params, keys: Sequence, windows, positions) -> np.ndarray:
        """One action per row.

        keys: hashable per-episode identities; windows: (n, W, *obs_shape)
        float32, LEFT-aligned (oldest frame first) and zero-padded on the
        right; positions: (n,) int — the EPISODE step of each row's newest
        frame.  Returns (n,) int32 actions.
        """
        if params is not self._last_params:
            if self._last_params is not None:
                self.pool.invalidate_all()
                self._stats["cache_invalidations"] += 1
            self._last_params = params

        windows = np.asarray(windows, np.float32)
        positions = np.asarray(positions, np.int64)
        n = windows.shape[0]
        generation = self.pool.generation
        actions = np.zeros((n,), np.int32)

        prefill_rows: List[int] = []
        decode_rows: List[int] = []
        slots = []
        for i in range(n):
            slot = self.pool.lookup(keys[i])
            if (slot is not None and slot.generation == generation
                    and slot.pos >= 0 and positions[i] == slot.pos + 1):
                decode_rows.append(i)
            else:
                if slot is None:
                    slot = self.pool.acquire(keys[i])
                else:
                    # episode restart or stale cache: recycle in place
                    if slot.generation != generation:
                        self._stats["stale_reprefills"] += 1
                    self.pool.reset_slot(slot)
                prefill_rows.append(i)
            slots.append(slot)

        if prefill_rows:
            self._run_prefill(params, prefill_rows, slots, windows,
                              positions, actions)
        if decode_rows:
            self._run_decode(params, decode_rows, slots, windows,
                             positions, actions)
        return actions

    def _pad(self, indices: List[int], bucket: int) -> np.ndarray:
        scratch = self.pool.scratch_index
        return np.asarray(indices + [scratch] * (bucket - len(indices)),
                          np.int32)

    def _next_step(self) -> int:
        step = self._step
        self._step = (self._step + 1) % STEP_MOD
        return step

    def _run_prefill(self, params, rows, slots, windows, positions, actions):
        g = len(rows)
        bucket = _bucket(g)
        w = self.window
        lengths = np.ones((bucket,), np.int32)
        batch = np.zeros((bucket, w) + windows.shape[2:], np.float32)
        for j, i in enumerate(rows):
            lengths[j] = min(positions[i] + 1, w)
            batch[j] = windows[i]
        idx = self._pad([slots[i].index for i in rows], bucket)
        sub = self.pool.gather(idx)
        acts, sub = self._prefill(params, sub, jnp.asarray(batch),
                                  jnp.asarray(lengths), self._key,
                                  self._next_step())
        self.pool.scatter(idx, sub)
        acts = np.asarray(acts)
        for j, i in enumerate(rows):
            slot = slots[i]
            slot.pos = int(positions[i])
            slot.cache_pos = int(lengths[j]) - 1
            actions[i] = acts[j]
        self._stats["prefill_batches"] += 1
        self._stats["prefill_rows"] += g

    def _run_decode(self, params, rows, slots, windows, positions, actions):
        g = len(rows)
        bucket = _bucket(g)
        w = self.window
        obs = np.zeros((bucket,) + windows.shape[2:], np.float32)
        pos = np.zeros((bucket,), np.int32)
        for j, i in enumerate(rows):
            # newest frame of a left-aligned window
            obs[j] = windows[i, min(int(positions[i]), w - 1)]
            pos[j] = slots[i].cache_pos + 1
        idx = self._pad([slots[i].index for i in rows], bucket)
        sub = self.pool.gather(idx)
        acts, sub = self._decode(params, sub, jnp.asarray(obs),
                                 jnp.asarray(pos), self._key,
                                 self._next_step())
        self.pool.scatter(idx, sub)
        acts = np.asarray(acts)
        for j, i in enumerate(rows):
            slot = slots[i]
            slot.pos = int(positions[i])
            slot.cache_pos += 1
            actions[i] = acts[j]
        self._stats["decode_batches"] += 1
        self._stats["decode_rows"] += g

    # ------------------------------------------------------------- lifecycle
    def release(self, key):
        self.pool.release(key)

    def release_client(self, client_id):
        self.pool.release_prefix(client_id)

    def stats(self) -> Dict[str, int]:
        s = dict(self._stats)
        s.update({f"pool_{k}": v for k, v in self.pool.stats.items()})
        s["pool_held_slots"] = self.pool.held()
        s["pool_utilization"] = self.pool.held() / max(self.pool.num_slots, 1)
        s["prefill_decode_ratio"] = (s["prefill_rows"]
                                     / max(s["decode_rows"], 1))
        return s
