"""Sequence double-DQN learning for the transformer policy.

The learner's forward pass is ``network.q_sequence`` — FULL-sequence
recompute over replayed (B, T) observation windows with the same banded
(``sliding_window``) attention the acting path evaluates incrementally
through the KV cache, so learner and actor compute the same function.

Objective: R2D2-style double Q-learning with 1-step-within-sequence
targets, prioritized by a max/mean mix of |TD|.  Positions whose attention
context would differ from acting (a mid-episode sequence's first
``window - 1`` steps see a truncated window) are masked out of the loss.
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.agents.common import (JaxLearner, LearnerState, fresh_copy,
                                 importance_weights)
from repro.core.types import EnvironmentSpec
from repro.policies import network
from repro.replay.dataset import ReplaySample


def make_learner(spec: EnvironmentSpec, cfg, iterator: Iterator, rng_key,
                 priority_update_cb=None) -> JaxLearner:
    num_actions = spec.actions.num_values
    obs_dim = int(np.prod(spec.observations.shape)) or 1
    arch = network.make_arch(cfg, num_actions)
    opt = optim.adam(cfg.learning_rate, clip=40.0)
    params = network.init(rng_key, arch, obs_dim, num_actions)
    state = LearnerState(params, fresh_copy(params), opt.init(params),
                         jnp.zeros((), jnp.int32))

    def loss_fn(params, target_params, sample: ReplaySample):
        seq = sample.data
        obs = seq["observation"].astype(jnp.float32)           # (B, T, ...)
        B, T = obs.shape[:2]
        obs = obs.reshape(B, T, -1)
        actions = seq["action"].astype(jnp.int32)
        rewards = seq["reward"].astype(jnp.float32)
        discounts = seq["discount"].astype(jnp.float32) * cfg.discount
        mask = seq["mask"].astype(jnp.float32)

        q = network.q_sequence(params, arch, obs)              # (B, T, A)
        q_target = network.q_sequence(target_params, arch, obs)
        # double Q with 1-step-within-sequence targets
        a_star = jnp.argmax(q[:, 1:], axis=-1)
        next_v = jnp.take_along_axis(q_target[:, 1:],
                                     a_star[..., None], -1)[..., 0]
        y = rewards[:, :-1] + discounts[:, :-1] * \
            jax.lax.stop_gradient(next_v)
        q_taken = jnp.take_along_axis(q[:, :-1],
                                      actions[:, :-1][..., None], -1)[..., 0]

        # acting-parity mask: a sequence that does NOT start at an episode
        # start has its first window-1 steps attend a truncated context the
        # actor never sees — drop them from the loss (burn-in analogue).
        start = seq["start_of_episode"][:, :1].astype(jnp.float32)   # (B, 1)
        t_idx = jnp.arange(T - 1, dtype=jnp.float32)[None, :]
        full_ctx = (t_idx >= cfg.window - 1).astype(jnp.float32)
        context_ok = jnp.clip(start + full_ctx, 0.0, 1.0)
        valid = mask[:, :-1] * context_ok
        td = (y - q_taken) * valid

        w = importance_weights(jnp.asarray(sample.info.probabilities),
                               cfg.importance_beta)
        loss = 0.5 * jnp.sum(w[:, None] * jnp.square(td)) / jnp.maximum(
            jnp.sum(valid), 1.0)
        abs_td = jnp.abs(td)
        prio = cfg.priority_eta * jnp.max(abs_td, axis=1) + \
            (1 - cfg.priority_eta) * jnp.mean(abs_td, axis=1)
        return loss, prio

    def update(state: LearnerState, sample: ReplaySample):
        (loss, prio), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, state.target_params, sample)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = optim.apply_updates(state.params, updates)
        steps = state.steps + 1
        target = optim.periodic_update(params, state.target_params, steps,
                                       cfg.target_update_period)
        return (LearnerState(params, target, opt_state, steps),
                {"loss": loss}, prio)

    return JaxLearner(state, update, iterator,
                      priority_update_cb=priority_update_cb)
