"""KV-cache slot pool for continuous-batching policy serving.

One device-resident batched cache (``transformer.init_cache`` over
``num_slots + 1`` rows) backs every in-flight episode: each episode owns a
SLOT (one batch row) for its lifetime and the server gathers the active
rows, runs one forward pass, and scatters the updated rows back — the
continuous batching ``launch/serve.py`` approximates with lockstep slot
recycling, made per-episode.

The extra row is a SCRATCH slot: batched forward passes are padded to
power-of-two buckets and every pad row gathers/scatters the scratch slot,
so padding never corrupts a live episode's cache.

Slot lifecycle:

- ``acquire(key)``: claim a free slot for episode ``key``; blocks up to
  ``timeout`` (backpressure) and raises ``CacheSlotsExhausted`` after it.
- ``release(key)`` / ``reset_slot(slot)``: recycle on episode end — the
  cache rows are NOT zeroed, position metadata alone invalidates them.
- ``invalidate_all()``: bump the pool generation after a server weight
  refresh; slots with a stale generation are re-prefilled before their
  next decode (stale-cache rejection — K/V computed under old weights
  never mixes with fresh queries).

Churn tolerance (repro.resilience): a worker that dies without calling
``release`` would leak its slots forever.  Every ``lookup``/``acquire``
touches the slot's last-used clock; when ``acquire`` finds the pool full
it first reaps slots idle for longer than ``reap_idle_s`` — a live episode
touches its slot every policy step, so only dead clients' slots qualify.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

import jax

from repro.models.config import ArchConfig
from repro.policies import network


class CacheSlotsExhausted(RuntimeError):
    """All cache slots are held by live episodes and none freed in time."""


class _Slot:
    __slots__ = ("index", "key", "pos", "cache_pos", "generation",
                 "last_used")

    def __init__(self, index: int):
        self.index = index
        self.key = None
        self.pos = -1             # last EPISODE step absorbed into the slot
        self.cache_pos = -1       # last CACHE position written (ring index
        #                           source; diverges from pos after a
        #                           mid-episode re-prefill, which restarts
        #                           the cache at window-relative positions)
        self.generation = -1
        self.last_used = 0.0      # monotonic clock of the last touch

    def reset(self, key, generation: int):
        self.key = key
        self.pos = -1
        self.cache_pos = -1
        self.generation = generation
        self.last_used = time.monotonic()


class KVCachePool:
    """``num_slots`` per-episode KV-cache slots over one batched cache."""

    def __init__(self, arch: ArchConfig, num_slots: int,
                 timeout_s: float = 5.0,
                 reap_idle_s: Optional[float] = 60.0):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        self.arch = arch
        self.num_slots = num_slots
        self.scratch_index = num_slots        # pad rows land here
        self.timeout_s = timeout_s
        # Under pool pressure, slots untouched for this long are reclaimed
        # (their client died without releasing).  None disables reaping.
        self.reap_idle_s = reap_idle_s
        self.cache = network.init_cache(arch, num_slots + 1)

        self._cond = threading.Condition()
        self._slots = [_Slot(i) for i in range(num_slots)]
        self._free = list(reversed(range(num_slots)))
        self._by_key: Dict[object, _Slot] = {}
        self.generation = 0
        self.stats = {"acquires": 0, "releases": 0, "exhausted_waits": 0,
                      "invalidations": 0, "reaped": 0}

    # --------------------------------------------------------- slot metadata
    def lookup(self, key) -> Optional[_Slot]:
        with self._cond:
            slot = self._by_key.get(key)
            if slot is not None:
                slot.last_used = time.monotonic()
            return slot

    def _release_locked(self, slot: _Slot):
        self._by_key.pop(slot.key, None)
        slot.key = None
        slot.pos = -1
        slot.cache_pos = -1
        self._free.append(slot.index)
        self._cond.notify_all()

    def _reap_idle_locked(self) -> int:
        """Reclaim slots whose holder went silent (worker churn): a live
        episode touches its slot every policy step, so ``reap_idle_s`` of
        silence means the client is gone.  Caller holds the lock."""
        if self.reap_idle_s is None:
            return 0
        cutoff = time.monotonic() - self.reap_idle_s
        stale = [s for s in self._by_key.values() if s.last_used < cutoff]
        for slot in stale:
            self._release_locked(slot)
        self.stats["reaped"] += len(stale)
        return len(stale)

    def acquire(self, key, timeout: Optional[float] = None) -> _Slot:
        """Claim a slot for ``key`` (idempotent: an existing slot is
        returned).  Blocks while all slots are held; raises
        ``CacheSlotsExhausted`` after ``timeout`` seconds."""
        timeout = self.timeout_s if timeout is None else timeout
        with self._cond:
            slot = self._by_key.get(key)
            if slot is not None:
                slot.last_used = time.monotonic()
                return slot
            if not self._free:
                self._reap_idle_locked()
            if not self._free:
                self.stats["exhausted_waits"] += 1
                self._cond.wait_for(lambda: bool(self._free), timeout)
            if not self._free and not self._reap_idle_locked():
                raise CacheSlotsExhausted(
                    f"all {self.num_slots} KV-cache slots held by live "
                    f"episodes (waited {timeout:.1f}s)")
            slot = self._slots[self._free.pop()]
            slot.reset(key, self.generation)
            self._by_key[key] = slot
            self.stats["acquires"] += 1
            return slot

    def release(self, key):
        """Recycle ``key``'s slot (episode end / client disconnect)."""
        with self._cond:
            slot = self._by_key.pop(key, None)
            if slot is None:
                return
            slot.key = None
            slot.pos = -1
            slot.cache_pos = -1
            self._free.append(slot.index)
            self.stats["releases"] += 1
            self._cond.notify_all()

    def release_prefix(self, key_prefix):
        """Release every slot whose key is a tuple starting with
        ``key_prefix`` — one client's whole env fleet on disconnect."""
        with self._cond:
            keys = [k for k in self._by_key
                    if isinstance(k, tuple) and k and k[0] == key_prefix]
        for k in keys:
            self.release(k)

    def reset_slot(self, slot: _Slot):
        """Recycle a held slot in place (same key, fresh episode): the next
        forward pass must PREFILL, never continue the stale positions."""
        with self._cond:
            slot.pos = -1
            slot.cache_pos = -1
            slot.generation = self.generation

    def invalidate_all(self):
        """Stale-cache rejection: mark every held slot's K/V as computed
        under old weights.  Slots stay held — the next pass re-prefills."""
        with self._cond:
            self.generation += 1
            self.stats["invalidations"] += 1

    def held(self) -> int:
        with self._cond:
            return len(self._by_key)

    # ------------------------------------------------------- device gather
    def gather(self, indices):
        """Sub-cache of rows ``indices`` (slot axis = axis 1: leaves are
        (layers, slots, L, kv_heads, head_dim))."""
        return jax.tree.map(lambda c: c[:, indices], self.cache)

    def scatter(self, indices, sub_cache):
        """Write updated rows back.  Duplicate indices (the scratch slot,
        repeated for every pad row) are harmless: last write wins and
        nothing reads the scratch row."""
        self.cache = jax.tree.map(
            lambda c, s: c.at[:, indices].set(s), self.cache, sub_cache)
