"""``TransformerPolicyBuilder``: the transformer policy as an Acme agent.

Implements the ``AgentBuilder`` protocol end to end: a sequence adder
through the existing prioritized replay, the sequence double-DQN learner
over replayed windows, windowed actors running incremental KV-cache decode
through a ``PolicyEngine``, and — for ``inference="server"`` programs — a
``TransformerInferenceServer`` doing continuous batching over per-episode
cache slots with the pallas ``decode_attention`` kernel on the forward
pass (``kernels/ref.py`` fallback off-TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.builders import AgentBuilder, BuilderOptions
from repro.core.types import EnvironmentSpec
from repro.policies import learning, network
from repro.policies.config import TransformerPolicyConfig
from repro.policies.engine import PolicyEngine


class TransformerPolicy:
    """The policy as a plain ``(params, key, obs) -> action`` callable.

    ``obs`` is ``{"window": (W, *obs_shape), "length": ()}`` — a full
    left-aligned observation window; the forward pass is FULL-sequence
    recompute (``q_sequence``), which makes this the parity oracle for the
    engine's incremental KV-cache decode.  It also carries the arch/shape
    metadata actors and servers derive engines from.
    """

    def __init__(self, arch, obs_shape, num_actions: int, epsilon: float,
                 backend: str, cache_slots: int, slot_timeout_s: float):
        self.arch = arch
        self.obs_shape = tuple(obs_shape)
        self.num_actions = num_actions
        self.epsilon = float(epsilon)
        self.backend = backend
        self.cache_slots = cache_slots
        self.slot_timeout_s = slot_timeout_s

    def __call__(self, params, key, obs):
        window = obs["window"].astype(jnp.float32)
        length = obs["length"].astype(jnp.int32)
        q = network.q_sequence(params, self.arch,
                               window.reshape(1, window.shape[0], -1))[0]
        q_last = q[jnp.maximum(length - 1, 0)]
        greedy = jnp.argmax(q_last).astype(jnp.int32)
        rand = jax.random.randint(key, (), 0, self.num_actions)
        explore = jax.random.uniform(key) < self.epsilon
        return jnp.where(explore, rand, greedy).astype(jnp.int32)

    def make_engine(self, *, num_slots: int, rng_seed: int = 0,
                    jit: bool = True) -> PolicyEngine:
        return PolicyEngine(self.arch, self.obs_shape, self.num_actions,
                            num_slots=num_slots, epsilon=self.epsilon,
                            backend=self.backend,
                            slot_timeout_s=self.slot_timeout_s,
                            rng_seed=rng_seed, jit=jit)


class TransformerPolicyBuilder(AgentBuilder):
    """DQN-style agent whose Q-network is a windowed transformer."""

    def __init__(self, spec: EnvironmentSpec,
                 cfg: TransformerPolicyConfig = None, seed: int = 0):
        cfg = cfg or TransformerPolicyConfig()
        super().__init__(BuilderOptions(
            variable_update_period=10,
            min_observations=cfg.min_replay_size,
            observations_per_step=max(float(cfg.period), 1.0),
            batch_size=cfg.batch_size))
        self.spec = spec
        self.cfg = cfg
        self.seed = seed
        self.num_actions = spec.actions.num_values
        self.arch = network.make_arch(cfg, self.num_actions)

    # ------------------------------------------------------- replay pipeline
    def make_replay(self):
        from repro import replay as r
        cfg = self.cfg
        if cfg.samples_per_insert > 0:
            limiter = r.SampleToInsertRatio(
                cfg.samples_per_insert, cfg.min_replay_size // cfg.period + 1,
                error_buffer=max(2 * cfg.samples_per_insert * cfg.batch_size,
                                 100))
        else:
            limiter = r.MinSize(max(cfg.min_replay_size // cfg.period, 1))
        return r.Table("replay", cfg.max_replay_size, r.Prioritized(),
                       limiter)

    def make_adder(self, table):
        from repro.adders.sequence import SequenceAdder
        return SequenceAdder(table, self.cfg.sequence_length,
                             period=self.cfg.period, priority=100.0)

    def make_dataset(self, table):
        from repro.replay import as_iterator
        return as_iterator(table, self.cfg.batch_size)

    def make_learner(self, iterator, priority_update_cb=None):
        return learning.make_learner(self.spec, self.cfg, iterator,
                                     jax.random.key(self.seed),
                                     priority_update_cb=priority_update_cb)

    # --------------------------------------------------------------- acting
    def make_policy(self, evaluation: bool = False):
        return TransformerPolicy(
            self.arch, self.spec.observations.shape, self.num_actions,
            epsilon=0.0 if evaluation else self.cfg.epsilon,
            backend=self.cfg.backend, cache_slots=self.cfg.cache_slots,
            slot_timeout_s=self.cfg.slot_timeout_s)

    def make_actor(self, policy, variable_client, adder, seed: int = 0):
        from repro.policies.actors import WindowedPolicyActor
        engine = policy.make_engine(num_slots=1, rng_seed=seed)
        return WindowedPolicyActor(engine, variable_client, adder)

    def make_batched_actor(self, policy, variable_client, adders,
                           seed: int = 0):
        from repro.policies.actors import BatchedWindowedPolicyActor
        engine = policy.make_engine(num_slots=max(len(adders), 1),
                                    rng_seed=seed)
        return BatchedWindowedPolicyActor(engine, variable_client, adders)

    # -------------------------------------------------------------- serving
    def make_inference_server(self, variable_source, *, max_batch_size: int,
                              max_wait_ms: float, update_period: int,
                              rng_seed: int = 0):
        from repro.policies.serving import TransformerInferenceServer
        policy = self.make_policy(evaluation=False)
        engine = policy.make_engine(
            num_slots=max(self.cfg.cache_slots, max_batch_size),
            rng_seed=rng_seed)
        return TransformerInferenceServer(
            engine, variable_source, max_batch_size=max_batch_size,
            max_wait_ms=max_wait_ms, update_period=update_period)

    def make_inference_actor(self, inference, adder=None, adders=None):
        from repro.policies.actors import WindowedInferenceClientActor
        if adders is not None:
            return WindowedInferenceClientActor(inference, adders=adders,
                                                batched=True)
        return WindowedInferenceClientActor(inference, adder=adder)
