"""Logical-axis sharding: rules mapping logical axes -> mesh axes.

Models annotate activations with *logical* axes (``'batch'``, ``'seq'``,
``'heads'``, ...).  A :class:`ShardingRules` context installed by the launcher
resolves those to physical mesh axes and applies
``jax.lax.with_sharding_constraint``.  Outside any context (CPU smoke tests)
annotations are no-ops, so model code is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# Baseline (paper-faithful + megatron tensor sharding) logical rules.
# 'data' carries the batch; 'model' carries heads / ff / experts / vocab.
BASE_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    # residual-stream activations: Megatron-style sequence parallelism —
    # between blocks activations are sharded along seq on the model axis
    # (XLA inserts the all-gather/reduce-scatter pairs around attention/mlp).
    "act_seq": "model",
    # q_seq: sequence-parallel attention — used when num_heads doesn't divide
    # the model axis (attention would otherwise replicate); shards the query
    # positions instead of heads, with no extra collectives beyond the K/V
    # gather.
    "q_seq": None,
    "seq": None,
    "kv_seq": None,
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    # experts first (expert parallelism when E divides the axis); otherwise
    # axis-dedup falls through to tensor-parallel expert ffn (expert_ff).
    "experts": "model",
    "expert_ff": "model",
    # MoE token groups follow the batch axes only: the expert-ffn einsum
    # needs g off the model axis (expert_ff lives there), and g-resharding
    # finer->coarser trips the partitioner's replicate-then-repartition
    # fallback (88GB buffers).  Keeping g@(pod,data) end-to-end avoids it.
    "moe_groups": ("pod", "data"),
    "vocab": "model",
    "embed_d": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "layers": None,
    "conv": None,
}


class ShardingRules:
    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, MeshAxes]] = None):
        self.mesh = mesh
        self.rules = dict(BASE_RULES)
        if rules:
            self.rules.update(rules)
        self._axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def mesh_axes(self, logical: Tuple[Optional[str], ...], dims=None) -> P:
        """Resolve logical axes to a PartitionSpec, dropping mesh axes that
        don't exist on this mesh, don't divide the dimension, or were already
        consumed by an earlier dim (a mesh axis may appear only once)."""
        out = []
        used = set()
        for i, name in enumerate(logical):
            axes = self.rules.get(name) if name else None
            if axes is None:
                out.append(None)
                continue
            if isinstance(axes, str):
                axes = (axes,)
            axes = tuple(a for a in axes if a in self._axis_sizes and a not in used)
            if not axes:
                out.append(None)
                continue
            if dims is not None:
                kept = []
                prod = 1
                for a in axes:
                    if dims[i] % (prod * self._axis_sizes[a]) == 0:
                        kept.append(a)
                        prod *= self._axis_sizes[a]
                axes = tuple(kept)
                if not axes:
                    out.append(None)
                    continue
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        return P(*out)

    def zero_spec(self, spec: P, dims) -> P:
        """ZeRO-style: additionally shard the first free, divisible dim over
        the data(+pod) axes — used for optimizer states (ZeRO-1)."""
        used = set()
        for entry in spec:
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                used.add(a)
        candidates = [a for a in ("data", "pod") if a in self._axis_sizes
                      and a not in used]
        if not candidates:
            return spec
        out = list(spec) + [None] * (len(dims) - len(spec))
        for i, d in enumerate(dims):
            if out[i] is not None:
                continue
            kept = []
            prod = 1
            for a in candidates:
                if d % (prod * self._axis_sizes[a]) == 0:
                    kept.append(a)
                    prod *= self._axis_sizes[a]
            if kept:
                out[i] = tuple(kept) if len(kept) > 1 else kept[0]
                break
        return P(*out)

    def named_sharding(self, logical, dims=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.mesh_axes(logical, dims))


_tls = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield rules
    finally:
        _tls.rules = prev


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate activation ``x`` with logical axes (no-op outside a context)."""
    r = current_rules()
    if r is None:
        return x
    spec = r.mesh_axes(tuple(logical), dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))
