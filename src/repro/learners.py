"""Multi-learner execution: per-shard learner replicas with parameter
averaging (the distributed-learner half of the §2.4 scaling story).

PR 2 sharded the replay *service*; this module shards the *learner*: N
replicas, each consuming its own replay shard's dataset, periodically
merged by a ``ParameterServer`` so actors, evaluators, and checkpoints
still see ONE logical learner.

Components:

- ``average_states(states)`` — the element-wise pytree mean over replica
  ``LearnerState``s (params, target params, optimizer moments, step
  counters).  Float leaves accumulate in float32 and cast back to their
  dtype; integer leaves (step counters) take an int64 floor mean, exact at
  any magnitude when replicas agree.  A single-state average is the
  identity (no float round-trip) — the 1-replica configuration is
  bit-equivalent to the plain learner.
- ``ParameterServer`` — the averaging rendezvous.  ``sync(replica_id,
  state)`` blocks until every replica has contributed the current round,
  then returns the merged state to all of them (synchronous all-reduce-style
  parameter averaging).  ``stop()`` releases blocked callers with ``None``
  so replica teardown can never deadlock on a half-filled round.
- ``MultiLearner`` — the single-logical-learner facade.  In the
  single-process path it IS the agent's learner: ``step()`` steps replicas
  sequentially round-robin and averages in-line every ``average_period``
  per-replica steps.  In distributed programs the replicas step on their own
  nodes and the facade only serves ``get_variables`` (last merged params)
  and ``state`` (the merged checkpoint view; assigning broadcasts a restore
  to every replica).  Deliberately NOT a ``Learner`` subclass: the ABC's
  concrete ``run(num_steps)`` would make launchers schedule the facade as a
  run-loop node.
- ``LearnerReplicaWorker`` — the program-graph node wrapping one replica:
  steps SGD until stopped, rendezvous at the parameter server every
  ``average_period`` steps, closes its prefetching dataset on stop.
- ``AsyncParameterService`` — the barrier-free alternative (PR 10): a
  key-value ``push(replica_id, state, step)`` / ``pull()`` service with
  staleness-weighted merging, so each replica pushes at its own cadence and
  pulls the latest blend without ever waiting for peers.  Selected via
  ``learner_sync="async"``; the barrier/quorum ``ParameterServer`` stays
  the default.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.telemetry import registry as _telemetry

# The declared RPC surface of the parameter-server node (what a multi-host
# backend would let remote replicas call).
PARAM_SERVER_INTERFACE = ("sync", "stats")

# The async service's surface: pushes and pulls never block on peers, so
# there is no rendezvous call to expose.
ASYNC_PARAM_SERVICE_INTERFACE = ("push", "pull", "stats")

# Staleness-weighted merge modes of the AsyncParameterService.
ASYNC_MERGE_MODES = ("mean", "ema", "step_weighted")

# Learner synchronization modes the execution layers accept.
LEARNER_SYNC_MODES = ("barrier", "quorum", "async")


def average_states(states: Sequence[Any]):
    """Element-wise mean over a sequence of identically-structured pytrees.

    Float leaves accumulate in float32 and cast back to their dtype;
    integer leaves (step counters) accumulate in int64 on host and take the
    floor mean — exact at ANY magnitude when the replicas agree (float32
    accumulation would silently round counters past 2^24).  With one state
    this is the identity — no round-trip, so 1-replica averaging is exactly
    the input state.
    """
    states = list(states)
    if not states:
        raise ValueError("average_states needs at least one state")
    if len(states) == 1:
        return states[0]

    def _mean(*leaves):
        dtype = jnp.asarray(leaves[0]).dtype
        if jnp.issubdtype(dtype, jnp.integer):
            total = np.sum([np.asarray(leaf, np.int64) for leaf in leaves],
                           axis=0)
            return jnp.asarray((total // len(leaves)).astype(dtype))
        total = leaves[0].astype(jnp.float32) if hasattr(leaves[0], "astype") \
            else jnp.asarray(leaves[0], jnp.float32)
        for leaf in leaves[1:]:
            total = total + jnp.asarray(leaf, jnp.float32)
        return (total / len(leaves)).astype(dtype)

    return jax.tree.map(_mean, *states)


def weighted_average_states(states: Sequence[Any],
                            weights: Sequence[float]):
    """Element-wise WEIGHTED mean over identically-structured pytrees — the
    staleness-weighted generalization of ``average_states``.

    Float leaves accumulate ``leaf * w`` in float32 under normalized
    weights and cast back to their dtype.  Integer leaves (step counters)
    keep the floor-mean contract: when every state agrees on a counter the
    result is that exact value at ANY magnitude (no float round-trip);
    disagreeing counters take the weighted floor mean in float64.  A
    single-state average is the identity regardless of its weight — which
    is what makes a 1-replica async blend bit-equivalent to the plain
    learner.
    """
    states = list(states)
    weights = [float(w) for w in weights]
    if not states:
        raise ValueError("weighted_average_states needs at least one state")
    if len(states) != len(weights):
        raise ValueError(
            f"got {len(states)} states but {len(weights)} weights")
    if any(w < 0.0 for w in weights) or sum(weights) <= 0.0:
        raise ValueError(
            f"weights must be non-negative with a positive sum, "
            f"got {weights}")
    if len(states) == 1:
        return states[0]
    total_w = sum(weights)
    norm = [w / total_w for w in weights]

    def _mean(*leaves):
        dtype = jnp.asarray(leaves[0]).dtype
        if jnp.issubdtype(dtype, jnp.integer):
            arrs = [np.asarray(leaf, np.int64) for leaf in leaves]
            if all(np.array_equal(arrs[0], a) for a in arrs[1:]):
                # agreement is exact at any magnitude — no float round-trip
                return jnp.asarray(arrs[0].astype(dtype))
            total = sum(w * a.astype(np.float64)
                        for w, a in zip(norm, arrs))
            return jnp.asarray(np.floor(total).astype(np.int64)
                               .astype(dtype))
        total = None
        for leaf, w in zip(leaves, norm):
            term = jnp.asarray(leaf, jnp.float32) * jnp.float32(w)
            total = term if total is None else total + term
        return total.astype(dtype)

    return jax.tree.map(_mean, *states)


class ParameterServer:
    """Synchronous parameter-averaging rendezvous for N learner replicas.

    Each replica calls ``sync(replica_id, state)`` after ``average_period``
    local SGD steps; the call blocks until all N replicas of the current
    round have contributed, then every caller receives the same merged
    state.  ``stop()`` wakes blocked callers with ``None`` (the replica
    keeps its own state and exits) — a dead or stopping replica can never
    wedge the others in a half-filled round forever only because fail-fast
    stop reaches this object like any other node instance.

    Quorum mode (``barrier_timeout_s`` + ``min_quorum``) relaxes the
    all-or-nothing barrier for elastic fleets: once a round's first
    contribution is ``barrier_timeout_s`` old, any waiter merges the >=
    ``min_quorum`` states that DID arrive, so a straggling, killed, or
    restoring replica delays a round by at most the timeout instead of
    stalling training forever.  A late replica that MISSED a merge adopts
    the latest merged state instead of contributing — its state predates
    the blend, so folding it in would merge the same logical round twice
    and drag the fleet back toward stale params (counted in
    ``stale_adoptions``).  ``invalidate(replica_id)`` withdraws a killed
    replica's pending contribution (the failover path calls it from
    ``LearnerReplicaWorker.mark_down``), so a restored replica's stale
    ``replica_id`` can never double-contribute to one round; its parked
    ``sync`` returns ``None`` without adopting anything over the restored
    state.  Defaults leave the strict barrier exactly as before.
    """

    def __init__(self, num_replicas: int, average_period: int,
                 barrier_timeout_s: Optional[float] = None,
                 min_quorum: Optional[int] = None):
        if num_replicas < 1:
            raise ValueError(
                f"num_replicas must be >= 1, got {num_replicas}")
        if average_period < 1:
            raise ValueError(
                f"average_period must be >= 1, got {average_period}")
        if barrier_timeout_s is not None and barrier_timeout_s <= 0:
            raise ValueError(f"barrier_timeout_s must be > 0, "
                             f"got {barrier_timeout_s}")
        if min_quorum is not None:
            if barrier_timeout_s is None:
                raise ValueError(
                    "min_quorum without barrier_timeout_s is meaningless: "
                    "a round only closes early when the barrier can time "
                    "out")
            if not 1 <= min_quorum <= num_replicas:
                raise ValueError(
                    f"min_quorum must be in [1, {num_replicas}], "
                    f"got {min_quorum}")
        self.num_replicas = num_replicas
        self.average_period = average_period
        # Quorum mode (both None by default — the all-or-nothing barrier is
        # unchanged): a round's deadline starts at its FIRST contribution;
        # past the deadline, any waiter holding >= min_quorum contributions
        # merges what arrived instead of stalling on stragglers.  Late or
        # restored replicas adopt the latest merged state on their next
        # sync rather than deadlocking the round.
        self.barrier_timeout_s = barrier_timeout_s
        self.min_quorum = (min_quorum if min_quorum is not None
                           else (1 if barrier_timeout_s is not None
                                 else None))
        self._cond = threading.Condition()
        self._pending: Dict[int, Any] = {}
        self._merged: Any = None
        self._rounds = 0
        self._quorum_merges = 0
        self._stale_adoptions = 0
        self._round_deadline: Optional[float] = None
        self._stopped = False
        # Per-replica bookkeeping for the quorum fix: the round count each
        # replica last observed (a replica that missed a merge adopts
        # rather than contributes) and an invalidation epoch bumped by
        # ``invalidate`` so a parked sync can be withdrawn.
        self._last_seen: Dict[int, int] = {}
        self._epoch: Dict[int, int] = {}
        # Lazy per-replica barrier-wait histograms: replicas first call
        # ``sync`` from their own worker threads/processes, well after the
        # run entrypoint configured telemetry.
        self._m_barrier: Dict[int, Any] = {}
        self._m_timeouts = None
        _telemetry.probe("learner/param_server", self.stats)

    @property
    def merged(self):
        """Last merged state (None before the first completed round)."""
        with self._cond:
            return self._merged

    @property
    def rounds(self) -> int:
        with self._cond:
            return self._rounds

    @property
    def stopped(self) -> bool:
        with self._cond:
            return self._stopped

    def merge(self, states: Sequence[Any]):
        """Average ``states`` and record the result as a completed round
        (the sequential single-process path, where one thread holds every
        replica and no barrier is needed)."""
        merged = average_states(states)
        with self._cond:
            self._merged = merged
            self._rounds += 1
        return merged

    def sync(self, replica_id: int, state):
        """Contribute ``state`` for the current round; block until all
        replicas have contributed; return the merged state (None once
        stopped)."""
        if not 0 <= replica_id < self.num_replicas:
            raise ValueError(
                f"replica_id must be in [0, {self.num_replicas}), "
                f"got {replica_id}")
        metric = self._m_barrier.get(replica_id)
        if metric is None and _telemetry.enabled():
            metric = self._m_barrier[replica_id] = _telemetry.histogram(
                f"learner/param_server/replica_{replica_id}/barrier_wait_ms")
        t0 = time.monotonic() if metric else 0.0
        result = self._sync(replica_id, state)
        if metric:
            metric.observe((time.monotonic() - t0) * 1000.0)
        return result

    def _sync(self, replica_id: int, state):
        with self._cond:
            if self._stopped:
                return None
            missed_merge = (self._merged is not None
                            and self._rounds
                            > self._last_seen.get(replica_id, 0))
            if self.barrier_timeout_s is not None and missed_merge:
                # Quorum fix: this replica missed a merge — its state was
                # computed from pre-merge params, so contributing it would
                # merge the same logical round a second time (and a lone
                # straggler would then REPLACE the blend with stale
                # params).  Adopt the latest blend instead; it contributes
                # fresh work next period.
                self._stale_adoptions += 1
                self._last_seen[replica_id] = self._rounds
                return self._merged
            round_at_entry = self._rounds
            epoch_at_entry = self._epoch.get(replica_id, 0)
            self._pending[replica_id] = state
            if self.barrier_timeout_s is not None \
                    and self._round_deadline is None:
                self._round_deadline = (time.monotonic()
                                        + self.barrier_timeout_s)
            if len(self._pending) == self.num_replicas:
                self._last_seen[replica_id] = self._rounds + 1
                return self._merge_pending_locked()
            while self._rounds == round_at_entry and not self._stopped \
                    and self._epoch.get(replica_id, 0) == epoch_at_entry:
                if self._quorum_due_locked():
                    self._last_seen[replica_id] = self._rounds + 1
                    return self._merge_pending_locked(timed_out=True)
                self._cond.wait(0.05)
            if self._epoch.get(replica_id, 0) != epoch_at_entry:
                # withdrawn by invalidate(): the caller keeps (or was just
                # restored to) its own state; nothing is adopted.
                return None
            if self._rounds == round_at_entry:   # woken by stop()
                return None
            self._last_seen[replica_id] = self._rounds
            return self._merged

    def invalidate(self, replica_id: int):
        """Withdraw ``replica_id``'s pending contribution (if any) and
        release its parked ``sync`` with ``None`` — called when the replica
        is killed/restored mid-round, so its stale pre-kill state cannot be
        folded into a round it no longer stands behind."""
        with self._cond:
            self._pending.pop(replica_id, None)
            self._epoch[replica_id] = self._epoch.get(replica_id, 0) + 1
            if not self._pending:
                # an empty round has no first contribution: the next one
                # must start a fresh deadline, not inherit a stale one
                self._round_deadline = None
            self._cond.notify_all()

    def _quorum_due_locked(self):
        """True when the round's deadline has passed with >= min_quorum
        contributions — the waiter that observes this performs the merge."""
        return (self._round_deadline is not None
                and time.monotonic() >= self._round_deadline
                and len(self._pending) >= self.min_quorum)

    def _merge_pending_locked(self, timed_out: bool = False):
        merged = average_states(
            [self._pending[i] for i in sorted(self._pending)])
        self._pending.clear()
        self._round_deadline = None
        self._merged = merged
        self._rounds += 1
        if timed_out:
            self._quorum_merges += 1
            if self._m_timeouts is None and _telemetry.enabled():
                self._m_timeouts = _telemetry.counter(
                    "learner/param_server/barrier_timeouts")
            if self._m_timeouts:
                self._m_timeouts.inc()
        self._cond.notify_all()
        return merged

    def stop(self):
        with self._cond:
            self._stopped = True
            self._pending.clear()
            self._round_deadline = None
            self._cond.notify_all()

    def stats(self) -> Dict[str, Any]:
        with self._cond:
            stats = {"num_replicas": self.num_replicas,
                     "average_period": self.average_period,
                     "rounds": self._rounds}
            if self.barrier_timeout_s is not None:
                stats["barrier_timeout_s"] = self.barrier_timeout_s
                stats["min_quorum"] = self.min_quorum
                stats["quorum_merges"] = self._quorum_merges
                stats["stale_adoptions"] = self._stale_adoptions
            return stats


class AsyncParameterService:
    """Barrier-free parameter exchange: push at your own cadence, pull the
    latest staleness-weighted blend, never wait for a peer.

    Each replica calls ``push(replica_id, state, step)`` after its local
    averaging period (``step`` is its cumulative SGD step count) and then
    ``pull()``s the current blend — both calls return immediately, so one
    slow replica can no longer stall the fleet (the ``learner_sync="async"``
    mode of ROADMAP open item 1).  The blend over the current per-replica
    contributions is recomputed lazily at pull time, only when a push
    changed something:

    - ``merge="mean"``: uniform weights — ``average_states`` semantics.
    - ``merge="ema"`` (default): weight ``ema_alpha ** age`` where ``age =
      max_step - step`` is the contribution's staleness in learner steps —
      stale replicas decay exponentially out of the blend.
    - ``merge="step_weighted"``: weight ``1 + step`` — contributions count
      in proportion to how much training they embody.

    A single contribution is returned VERBATIM (``weighted_average_states``
    identity), so 1-replica async training is bit-equivalent to the plain
    learner.  ``staleness_bound`` drops contributions older than the bound
    from the blend entirely (the freshest contribution always survives).

    The service is ``Recoverable`` (``state_dict``/``load_state_dict``) and
    supports simulated death (``mark_down`` makes push/pull raise
    ``ServiceUnavailable`` until ``mark_up``), so the ``ServiceWatchdog``
    snapshots and restores it at the same courier address like any other
    service; replicas degrade (skip the exchange) through the restart
    window instead of dying.
    """

    def __init__(self, num_replicas: int, merge: str = "ema",
                 ema_alpha: float = 0.5,
                 staleness_bound: Optional[int] = None):
        if num_replicas < 1:
            raise ValueError(
                f"num_replicas must be >= 1, got {num_replicas}")
        if merge not in ASYNC_MERGE_MODES:
            raise ValueError(f"merge must be one of {ASYNC_MERGE_MODES}, "
                             f"got {merge!r}")
        if not 0.0 < ema_alpha <= 1.0:
            raise ValueError(f"ema_alpha must be in (0, 1], got {ema_alpha}")
        if staleness_bound is not None and staleness_bound < 1:
            raise ValueError(f"staleness_bound must be >= 1, "
                             f"got {staleness_bound}")
        self.num_replicas = num_replicas
        self.merge = merge
        self.ema_alpha = float(ema_alpha)
        self.staleness_bound = staleness_bound
        self._lock = threading.Lock()
        # replica_id -> (state, step): the latest push per replica.
        self._contrib: Dict[int, Any] = {}
        self._max_step = 0
        self._blend = None
        self._blend_age = 0
        self._dirty = False
        self._pushes = 0
        self._pulls = 0
        self._merges = 0
        self._dropped_stale = 0
        self._stopped = False
        self._down = threading.Event()
        # Lazy histograms: replicas push from their own threads/processes,
        # well after the run entrypoint configured telemetry.
        self._m_push_staleness = None
        self._m_pull_age = None
        _telemetry.probe("learner/param_service", self.stats)

    # ------------------------------------------------------------- data path
    def push(self, replica_id: int, state, step: int):
        """Record ``replica_id``'s state at cumulative SGD step ``step``;
        returns immediately (no rendezvous)."""
        if not 0 <= replica_id < self.num_replicas:
            raise ValueError(
                f"replica_id must be in [0, {self.num_replicas}), "
                f"got {replica_id}")
        step = int(step)
        if step < 0:
            raise ValueError(f"step must be >= 0, got {step}")
        self._check_up()
        with self._lock:
            if self._stopped:
                return
            staleness = max(self._max_step - step, 0)
            self._contrib[replica_id] = (state, step)
            self._max_step = max(self._max_step, step)
            self._pushes += 1
            self._dirty = True
        if self._m_push_staleness is None and _telemetry.enabled():
            self._m_push_staleness = _telemetry.histogram(
                "learner/push_staleness")
        if self._m_push_staleness:
            self._m_push_staleness.observe(staleness)

    def pull(self):
        """The latest blend over the current contributions (recomputed only
        when a push changed something); ``None`` before the first push or
        once stopped."""
        self._check_up()
        with self._lock:
            if self._stopped:
                return None
            self._pulls += 1
            if not self._contrib:
                return None
            if self._dirty:
                self._recompute_locked()
            blend, age = self._blend, self._blend_age
        if self._m_pull_age is None and _telemetry.enabled():
            self._m_pull_age = _telemetry.histogram("learner/pull_age_steps")
        if self._m_pull_age:
            self._m_pull_age.observe(age)
        return blend

    def _recompute_locked(self):
        entries = sorted(self._contrib.items())
        kept = entries
        if self.staleness_bound is not None:
            kept = [(rid, (state, step)) for rid, (state, step) in entries
                    if self._max_step - step <= self.staleness_bound]
            self._dropped_stale += len(entries) - len(kept)
            if not kept:   # never blend nothing: keep the freshest
                kept = [max(entries, key=lambda e: e[1][1])]
        states = [state for _, (state, _) in kept]
        ages = [self._max_step - step for _, (_, step) in kept]
        if len(states) == 1:
            # verbatim — the 1-replica parity guarantee
            self._blend = states[0]
        elif self.merge == "mean":
            self._blend = average_states(states)
        elif self.merge == "ema":
            weights = [self.ema_alpha ** age for age in ages]
            self._blend = weighted_average_states(states, weights)
        else:   # step_weighted
            weights = [1.0 + step for _, (_, step) in kept]
            self._blend = weighted_average_states(states, weights)
        self._blend_age = max(ages)
        self._merges += 1
        self._dirty = False

    def invalidate(self, replica_id: int):
        """Drop ``replica_id``'s contribution from future blends — called
        when the replica is killed, so a restored replica's stale pre-kill
        state stops weighing on the fleet."""
        with self._lock:
            if self._contrib.pop(replica_id, None) is not None:
                self._dirty = True

    # ------------------------------------------------------------ lifecycle
    @property
    def rounds(self) -> int:
        """Blend recomputations so far (the async analogue of the barrier
        server's averaging rounds)."""
        with self._lock:
            return self._merges

    @property
    def stopped(self) -> bool:
        with self._lock:
            return self._stopped

    def stop(self):
        with self._lock:
            self._stopped = True

    # --------------------------------------------------- service failover
    def mark_down(self):
        """Simulate abrupt service death: push/pull raise
        ``ServiceUnavailable`` until ``mark_up`` (replicas degrade — skip
        the exchange and keep training on local state).  Metadata reads
        (``stats``/``state_dict``) stay available for the watchdog."""
        self._down.set()

    def mark_up(self):
        self._down.clear()

    def _check_up(self):
        if self._down.is_set():
            from repro.distributed.courier import ServiceUnavailable
            raise ServiceUnavailable(
                "async parameter service is down (simulated failure; "
                "awaiting failover)")

    def activity(self) -> int:
        """Monotonic progress counter for chaos kill triggers."""
        with self._lock:
            return self._pushes + self._pulls

    def state_dict(self) -> Dict[str, Any]:
        """Snapshot for service failover: contributions (replicas swap
        their state pytrees atomically, so concurrent reads are
        consistent), the step high-water mark, and the counters."""
        with self._lock:
            return {"contrib": dict(self._contrib),
                    "max_step": self._max_step,
                    "pushes": self._pushes,
                    "pulls": self._pulls,
                    "merges": self._merges,
                    "dropped_stale": self._dropped_stale}

    def load_state_dict(self, state: Dict[str, Any]):
        with self._lock:
            self._contrib = dict(state["contrib"])
            self._max_step = int(state["max_step"])
            self._pushes = int(state["pushes"])
            self._pulls = int(state["pulls"])
            self._merges = int(state["merges"])
            self._dropped_stale = int(state.get("dropped_stale", 0))
            self._blend = None
            self._blend_age = 0
            self._dirty = True   # recompute from restored contributions

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict[str, Any]:
        with self._lock:
            stats = {"num_replicas": self.num_replicas,
                     "merge": self.merge,
                     "pushes": self._pushes,
                     "pulls": self._pulls,
                     "merges": self._merges,
                     "contributors": len(self._contrib),
                     "max_step": self._max_step}
            if self.staleness_bound is not None:
                stats["staleness_bound"] = self.staleness_bound
                stats["dropped_stale"] = self._dropped_stale
            return stats


class MultiLearner:
    """N learner replicas behind the single-learner surface.

    Single-process runs step it directly: ``step()`` advances one replica
    per call in round-robin order and averages all replicas in-line once
    every replica has taken ``average_period`` steps since the last merge —
    the sequential equivalent of the distributed barrier.  Distributed runs
    never call ``step()``; replica nodes step themselves and rendezvous at
    the shared ``ParameterServer``, while this facade serves the merged
    view to actors (``get_variables``) and checkpoints (``state``).
    """

    def __init__(self, replicas: Sequence[Any], average_period: int = 50,
                 param_server: Optional[ParameterServer] = None,
                 workers: Optional[Sequence["LearnerReplicaWorker"]] = None,
                 async_service: Optional[AsyncParameterService] = None):
        self._replicas = list(replicas)
        if not self._replicas:
            raise ValueError("MultiLearner needs at least one replica")
        if average_period < 1:
            raise ValueError(
                f"average_period must be >= 1, got {average_period}")
        if async_service is not None and param_server is not None:
            raise ValueError(
                "pass either param_server (barrier/quorum) or "
                "async_service (learner_sync='async'), not both")
        self._period = average_period
        self._async = async_service
        self._server = param_server if async_service is not None else (
            param_server or ParameterServer(
                len(self._replicas), average_period))
        self._workers = list(workers) if workers is not None else None
        self._step_counts = [0] * len(self._replicas)
        self._cursor = 0

    @property
    def num_replicas(self) -> int:
        return len(self._replicas)

    @property
    def average_period(self) -> int:
        return self._period

    @property
    def replicas(self) -> List[Any]:
        return list(self._replicas)

    @property
    def param_server(self) -> Optional[ParameterServer]:
        """The barrier/quorum rendezvous (None in async mode)."""
        return self._server

    @property
    def async_service(self) -> Optional[AsyncParameterService]:
        """The push/pull service (None in barrier/quorum mode)."""
        return self._async

    @property
    def next_replica(self) -> int:
        """Index of the replica the next sequential ``step()`` will
        advance — what a lockstep scheduler must gate on (the step samples
        that replica's shard only, not the aggregate table)."""
        return self._cursor

    # ------------------------------------------------------- learner surface
    def step(self) -> Dict[str, Any]:
        """Sequential round-robin: one replica step per call.  Barrier mode
        merges in-line once every replica has taken ``average_period`` steps
        (a full cycle of ``num_replicas * average_period`` calls) and every
        replica adopts the merge.  Async mode has no fleet-wide rendezvous:
        each replica pushes/pulls at ITS OWN period boundary and adopts the
        current blend — with one replica the blend is its own state
        verbatim, so the schedule is bit-identical to the plain learner."""
        i = self._cursor
        metrics = self._replicas[i].step()
        self._step_counts[i] += 1
        self._cursor = (i + 1) % len(self._replicas)
        if self._async is not None:
            if self._step_counts[i] % self._period == 0:
                self._async.push(i, self._replicas[i].state,
                                 self._step_counts[i])
                blend = self._async.pull()
                if blend is not None:
                    self._replicas[i].state = blend
        elif self._cursor == 0 \
                and self._step_counts[-1] % self._period == 0:
            merged = self._server.merge([r.state for r in self._replicas])
            for replica in self._replicas:
                replica.state = merged
        return metrics

    def get_variables(self, names: Sequence[str] = ("policy",)):
        """Actors see ONE logical learner: the merged view of the replicas'
        CURRENT params (each replica swaps its immutable state atomically,
        so the average is over consistent snapshots).  Only params are
        averaged here — this is the weight-sync hot path, and the optimizer
        moments/target params of the full ``state`` view would be computed
        just to be discarded.  With one replica this is exactly that
        replica's live params — which is what makes the 1-replica
        configuration serve bit-identical weights to the plain learner."""
        params_per_replica = [getattr(r.state, "params", None)
                              for r in self._replicas]
        if any(p is None for p in params_per_replica):
            return self._replicas[0].get_variables(names)
        params = jax.tree.map(np.asarray, average_states(params_per_replica))
        return [params for _ in (names or ("policy",))]

    @property
    def state(self):
        """The merged checkpoint view: the average of every replica's
        current state (identity for one replica)."""
        return average_states([r.state for r in self._replicas])

    @state.setter
    def state(self, merged):
        """Restore: broadcast a (checkpointed) merged state to all
        replicas."""
        for replica in self._replicas:
            replica.state = merged

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        """Per-replica executed step counts + averaging rounds — what
        ``result.extras['learners']`` reports."""
        if self._workers is not None:
            per_replica = [w.steps_taken for w in self._workers]
        else:
            per_replica = list(self._step_counts)
        stats = {"num_replicas": len(self._replicas),
                 "average_period": self._period,
                 "rounds": (self._async.rounds if self._async is not None
                            else self._server.rounds),
                 "per_replica_steps": per_replica}
        if self._async is not None:
            stats["sync"] = "async"
            stats["service"] = self._async.stats()
        return stats


class LearnerReplicaWorker:
    """One learner replica as a program-graph node (a run+serve hybrid like
    the single-learner node): steps SGD on its own shard's dataset until
    stopped, rendezvous at the ``ParameterServer`` every ``average_period``
    steps (``param_server=None`` skips the rendezvous — the plain
    single-learner node is the degenerate case), and serves
    ``get_variables`` for debugging/conformance.

    ``dataset`` (a ``PrefetchingDataset`` when prefetch is enabled) is
    closed on stop and on run-loop exit, so replica teardown cannot leak
    sampler threads across sequential runs in one process.
    """

    def __init__(self, learner, param_server=None, replica_id: int = 0,
                 average_period: int = 1, max_steps: Optional[int] = None,
                 dataset=None, shard=None, sync_mode: str = "barrier"):
        if average_period < 1:
            raise ValueError(
                f"average_period must be >= 1, got {average_period}")
        if sync_mode not in ("barrier", "async"):
            # quorum is a ParameterServer configuration, not a different
            # call path — the worker only distinguishes sync vs push/pull
            raise ValueError(f"sync_mode must be 'barrier' or 'async', "
                             f"got {sync_mode!r}")
        self.learner = learner
        self.param_server = param_server
        self.replica_id = replica_id
        self.average_period = average_period
        self.max_steps = max_steps
        self.dataset = dataset
        self.shard = shard
        self.sync_mode = sync_mode
        self.steps_taken = 0
        self._stop = threading.Event()
        self._down = threading.Event()
        self._m_degraded = None

    def run(self):
        local = 0
        try:
            while True:
                if self._stop.is_set():
                    return
                if self._down.is_set():
                    # simulated death (service failover): pause until the
                    # watchdog restores this replica's state and marks it up
                    time.sleep(0.02)
                    continue
                if self.max_steps is not None \
                        and self.steps_taken >= self.max_steps:
                    return
                try:
                    self.learner.step()
                except ConnectionError:
                    if self._stop.is_set():
                        return
                    # this replica's replay shard is in its restart window:
                    # degrade (skip the step) instead of dying and burning
                    # a restart budget that belongs to real failures
                    self._degraded_metric_inc()
                    time.sleep(0.05)
                    continue
                except Exception:
                    if self._stop.is_set():
                        return
                    raise
                self.steps_taken += 1
                local += 1
                if self.param_server is not None \
                        and local >= self.average_period:
                    local = 0
                    try:
                        if self.sync_mode == "async":
                            # push-then-pull, never waiting on peers: one
                            # slow replica costs the blend staleness, not
                            # fleet throughput
                            self.param_server.push(self.replica_id,
                                                   self.learner.state,
                                                   self.steps_taken)
                            merged = self.param_server.pull()
                        else:
                            merged = self.param_server.sync(
                                self.replica_id, self.learner.state)
                    except ConnectionError:
                        if self._stop.is_set():
                            return
                        self._degraded_metric_inc()
                        continue   # keep local state; rejoin next period
                    if merged is None:
                        if getattr(self.param_server, "stopped", False):
                            return   # server stopped mid-round
                        # withdrawn (invalidate during failover) or empty:
                        # keep local state; the down-check above pauses us
                        continue
                    self.learner.state = merged
        finally:
            self._close_dataset()

    def stop(self):
        self._stop.set()
        # wake a step() blocked on the prefetch queue: close() sets the
        # dataset's stop event, its next() raises the "stopped" timeout,
        # and the run loop exits through the stop check above.
        self._close_dataset()

    # --------------------------------------------------- service failover
    def mark_down(self):
        """Simulate abrupt replica death: the run loop pauses (no SGD, no
        rendezvous — with quorum averaging the other replicas keep merging
        without it) until the watchdog restores and ``mark_up``s it.  Any
        contribution parked at the parameter server is withdrawn — a dead
        replica's stale state must not be folded into a round (and the
        restored state must not be overwritten by a merge it predates)."""
        self._down.set()
        invalidate = getattr(self.param_server, "invalidate", None)
        if callable(invalidate):
            try:
                invalidate(self.replica_id)
            except ConnectionError:
                pass   # the service itself is down; nothing parked survives

    def mark_up(self):
        self._down.clear()

    def state_dict(self) -> Dict[str, Any]:
        """Snapshot for service failover: the learner pytree (replicas swap
        it atomically, so a concurrent read is a consistent state) plus the
        step count the restart accounting resumes from."""
        return {"learner_state": self.learner.state,
                "steps_taken": self.steps_taken}

    def load_state_dict(self, state: Dict[str, Any]):
        self.learner.state = state["learner_state"]
        self.steps_taken = int(state["steps_taken"])

    def _degraded_metric_inc(self):
        if self._m_degraded is None:
            if not _telemetry.enabled():
                return
            self._m_degraded = _telemetry.counter(
                f"resilience/learner_replica_{self.replica_id}/skipped_steps")
        self._m_degraded.inc()

    def get_variables(self, names: Sequence[str] = ()):
        return self.learner.get_variables(names)

    def _close_dataset(self):
        if self.dataset is not None and hasattr(self.dataset, "close"):
            self.dataset.close()
