"""Multi-learner execution: per-shard learner replicas with parameter
averaging (the distributed-learner half of the §2.4 scaling story).

PR 2 sharded the replay *service*; this module shards the *learner*: N
replicas, each consuming its own replay shard's dataset, periodically
merged by a ``ParameterServer`` so actors, evaluators, and checkpoints
still see ONE logical learner.

Components:

- ``average_states(states)`` — the element-wise pytree mean over replica
  ``LearnerState``s (params, target params, optimizer moments, step
  counters).  Float leaves accumulate in float32 and cast back to their
  dtype; integer leaves (step counters) take an int64 floor mean, exact at
  any magnitude when replicas agree.  A single-state average is the
  identity (no float round-trip) — the 1-replica configuration is
  bit-equivalent to the plain learner.
- ``ParameterServer`` — the averaging rendezvous.  ``sync(replica_id,
  state)`` blocks until every replica has contributed the current round,
  then returns the merged state to all of them (synchronous all-reduce-style
  parameter averaging).  ``stop()`` releases blocked callers with ``None``
  so replica teardown can never deadlock on a half-filled round.
- ``MultiLearner`` — the single-logical-learner facade.  In the
  single-process path it IS the agent's learner: ``step()`` steps replicas
  sequentially round-robin and averages in-line every ``average_period``
  per-replica steps.  In distributed programs the replicas step on their own
  nodes and the facade only serves ``get_variables`` (last merged params)
  and ``state`` (the merged checkpoint view; assigning broadcasts a restore
  to every replica).  Deliberately NOT a ``Learner`` subclass: the ABC's
  concrete ``run(num_steps)`` would make launchers schedule the facade as a
  run-loop node.
- ``LearnerReplicaWorker`` — the program-graph node wrapping one replica:
  steps SGD until stopped, rendezvous at the parameter server every
  ``average_period`` steps, closes its prefetching dataset on stop.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.telemetry import registry as _telemetry

# The declared RPC surface of the parameter-server node (what a multi-host
# backend would let remote replicas call).
PARAM_SERVER_INTERFACE = ("sync", "stats")


def average_states(states: Sequence[Any]):
    """Element-wise mean over a sequence of identically-structured pytrees.

    Float leaves accumulate in float32 and cast back to their dtype;
    integer leaves (step counters) accumulate in int64 on host and take the
    floor mean — exact at ANY magnitude when the replicas agree (float32
    accumulation would silently round counters past 2^24).  With one state
    this is the identity — no round-trip, so 1-replica averaging is exactly
    the input state.
    """
    states = list(states)
    if not states:
        raise ValueError("average_states needs at least one state")
    if len(states) == 1:
        return states[0]

    def _mean(*leaves):
        dtype = jnp.asarray(leaves[0]).dtype
        if jnp.issubdtype(dtype, jnp.integer):
            total = np.sum([np.asarray(leaf, np.int64) for leaf in leaves],
                           axis=0)
            return jnp.asarray((total // len(leaves)).astype(dtype))
        total = leaves[0].astype(jnp.float32) if hasattr(leaves[0], "astype") \
            else jnp.asarray(leaves[0], jnp.float32)
        for leaf in leaves[1:]:
            total = total + jnp.asarray(leaf, jnp.float32)
        return (total / len(leaves)).astype(dtype)

    return jax.tree.map(_mean, *states)


class ParameterServer:
    """Synchronous parameter-averaging rendezvous for N learner replicas.

    Each replica calls ``sync(replica_id, state)`` after ``average_period``
    local SGD steps; the call blocks until all N replicas of the current
    round have contributed, then every caller receives the same merged
    state.  ``stop()`` wakes blocked callers with ``None`` (the replica
    keeps its own state and exits) — a dead or stopping replica can never
    wedge the others in a half-filled round forever only because fail-fast
    stop reaches this object like any other node instance.

    Quorum mode (``barrier_timeout_s`` + ``min_quorum``) relaxes the
    all-or-nothing barrier for elastic fleets: once a round's first
    contribution is ``barrier_timeout_s`` old, any waiter merges the >=
    ``min_quorum`` states that DID arrive, so a straggling, killed, or
    restoring replica delays a round by at most the timeout instead of
    stalling training forever.  Late replicas fold into the next round and
    receive its merged state.  Defaults leave the strict barrier exactly
    as before.
    """

    def __init__(self, num_replicas: int, average_period: int,
                 barrier_timeout_s: Optional[float] = None,
                 min_quorum: Optional[int] = None):
        if num_replicas < 1:
            raise ValueError(
                f"num_replicas must be >= 1, got {num_replicas}")
        if average_period < 1:
            raise ValueError(
                f"average_period must be >= 1, got {average_period}")
        if barrier_timeout_s is not None and barrier_timeout_s <= 0:
            raise ValueError(f"barrier_timeout_s must be > 0, "
                             f"got {barrier_timeout_s}")
        if min_quorum is not None:
            if barrier_timeout_s is None:
                raise ValueError(
                    "min_quorum without barrier_timeout_s is meaningless: "
                    "a round only closes early when the barrier can time "
                    "out")
            if not 1 <= min_quorum <= num_replicas:
                raise ValueError(
                    f"min_quorum must be in [1, {num_replicas}], "
                    f"got {min_quorum}")
        self.num_replicas = num_replicas
        self.average_period = average_period
        # Quorum mode (both None by default — the all-or-nothing barrier is
        # unchanged): a round's deadline starts at its FIRST contribution;
        # past the deadline, any waiter holding >= min_quorum contributions
        # merges what arrived instead of stalling on stragglers.  Late or
        # restored replicas adopt the latest merged state on their next
        # sync rather than deadlocking the round.
        self.barrier_timeout_s = barrier_timeout_s
        self.min_quorum = (min_quorum if min_quorum is not None
                           else (1 if barrier_timeout_s is not None
                                 else None))
        self._cond = threading.Condition()
        self._pending: Dict[int, Any] = {}
        self._merged: Any = None
        self._rounds = 0
        self._quorum_merges = 0
        self._round_deadline: Optional[float] = None
        self._stopped = False
        # Lazy per-replica barrier-wait histograms: replicas first call
        # ``sync`` from their own worker threads/processes, well after the
        # run entrypoint configured telemetry.
        self._m_barrier: Dict[int, Any] = {}
        self._m_timeouts = None
        _telemetry.probe("learner/param_server", self.stats)

    @property
    def merged(self):
        """Last merged state (None before the first completed round)."""
        with self._cond:
            return self._merged

    @property
    def rounds(self) -> int:
        with self._cond:
            return self._rounds

    @property
    def stopped(self) -> bool:
        with self._cond:
            return self._stopped

    def merge(self, states: Sequence[Any]):
        """Average ``states`` and record the result as a completed round
        (the sequential single-process path, where one thread holds every
        replica and no barrier is needed)."""
        merged = average_states(states)
        with self._cond:
            self._merged = merged
            self._rounds += 1
        return merged

    def sync(self, replica_id: int, state):
        """Contribute ``state`` for the current round; block until all
        replicas have contributed; return the merged state (None once
        stopped)."""
        if not 0 <= replica_id < self.num_replicas:
            raise ValueError(
                f"replica_id must be in [0, {self.num_replicas}), "
                f"got {replica_id}")
        metric = self._m_barrier.get(replica_id)
        if metric is None and _telemetry.enabled():
            metric = self._m_barrier[replica_id] = _telemetry.histogram(
                f"learner/param_server/replica_{replica_id}/barrier_wait_ms")
        t0 = time.monotonic() if metric else 0.0
        result = self._sync(replica_id, state)
        if metric:
            metric.observe((time.monotonic() - t0) * 1000.0)
        return result

    def _sync(self, replica_id: int, state):
        with self._cond:
            if self._stopped:
                return None
            round_at_entry = self._rounds
            self._pending[replica_id] = state
            if self.barrier_timeout_s is not None \
                    and self._round_deadline is None:
                self._round_deadline = (time.monotonic()
                                        + self.barrier_timeout_s)
            if len(self._pending) == self.num_replicas:
                return self._merge_pending_locked()
            while self._rounds == round_at_entry and not self._stopped:
                if self._quorum_due_locked():
                    return self._merge_pending_locked(timed_out=True)
                self._cond.wait(0.05)
            if self._rounds == round_at_entry:   # woken by stop()
                return None
            return self._merged

    def _quorum_due_locked(self):
        """True when the round's deadline has passed with >= min_quorum
        contributions — the waiter that observes this performs the merge."""
        return (self._round_deadline is not None
                and time.monotonic() >= self._round_deadline
                and len(self._pending) >= self.min_quorum)

    def _merge_pending_locked(self, timed_out: bool = False):
        merged = average_states(
            [self._pending[i] for i in sorted(self._pending)])
        self._pending.clear()
        self._round_deadline = None
        self._merged = merged
        self._rounds += 1
        if timed_out:
            self._quorum_merges += 1
            if self._m_timeouts is None and _telemetry.enabled():
                self._m_timeouts = _telemetry.counter(
                    "learner/param_server/barrier_timeouts")
            if self._m_timeouts:
                self._m_timeouts.inc()
        self._cond.notify_all()
        return merged

    def stop(self):
        with self._cond:
            self._stopped = True
            self._pending.clear()
            self._round_deadline = None
            self._cond.notify_all()

    def stats(self) -> Dict[str, Any]:
        with self._cond:
            stats = {"num_replicas": self.num_replicas,
                     "average_period": self.average_period,
                     "rounds": self._rounds}
            if self.barrier_timeout_s is not None:
                stats["barrier_timeout_s"] = self.barrier_timeout_s
                stats["min_quorum"] = self.min_quorum
                stats["quorum_merges"] = self._quorum_merges
            return stats


class MultiLearner:
    """N learner replicas behind the single-learner surface.

    Single-process runs step it directly: ``step()`` advances one replica
    per call in round-robin order and averages all replicas in-line once
    every replica has taken ``average_period`` steps since the last merge —
    the sequential equivalent of the distributed barrier.  Distributed runs
    never call ``step()``; replica nodes step themselves and rendezvous at
    the shared ``ParameterServer``, while this facade serves the merged
    view to actors (``get_variables``) and checkpoints (``state``).
    """

    def __init__(self, replicas: Sequence[Any], average_period: int = 50,
                 param_server: Optional[ParameterServer] = None,
                 workers: Optional[Sequence["LearnerReplicaWorker"]] = None):
        self._replicas = list(replicas)
        if not self._replicas:
            raise ValueError("MultiLearner needs at least one replica")
        if average_period < 1:
            raise ValueError(
                f"average_period must be >= 1, got {average_period}")
        self._period = average_period
        self._server = param_server or ParameterServer(
            len(self._replicas), average_period)
        self._workers = list(workers) if workers is not None else None
        self._step_counts = [0] * len(self._replicas)
        self._cursor = 0

    @property
    def num_replicas(self) -> int:
        return len(self._replicas)

    @property
    def average_period(self) -> int:
        return self._period

    @property
    def replicas(self) -> List[Any]:
        return list(self._replicas)

    @property
    def param_server(self) -> ParameterServer:
        return self._server

    @property
    def next_replica(self) -> int:
        """Index of the replica the next sequential ``step()`` will
        advance — what a lockstep scheduler must gate on (the step samples
        that replica's shard only, not the aggregate table)."""
        return self._cursor

    # ------------------------------------------------------- learner surface
    def step(self) -> Dict[str, Any]:
        """Sequential round-robin: one replica step per call; a full cycle
        of ``num_replicas * average_period`` calls ends in a merge that
        every replica adopts."""
        i = self._cursor
        metrics = self._replicas[i].step()
        self._step_counts[i] += 1
        self._cursor = (i + 1) % len(self._replicas)
        if self._cursor == 0 \
                and self._step_counts[-1] % self._period == 0:
            merged = self._server.merge([r.state for r in self._replicas])
            for replica in self._replicas:
                replica.state = merged
        return metrics

    def get_variables(self, names: Sequence[str] = ("policy",)):
        """Actors see ONE logical learner: the merged view of the replicas'
        CURRENT params (each replica swaps its immutable state atomically,
        so the average is over consistent snapshots).  Only params are
        averaged here — this is the weight-sync hot path, and the optimizer
        moments/target params of the full ``state`` view would be computed
        just to be discarded.  With one replica this is exactly that
        replica's live params — which is what makes the 1-replica
        configuration serve bit-identical weights to the plain learner."""
        params_per_replica = [getattr(r.state, "params", None)
                              for r in self._replicas]
        if any(p is None for p in params_per_replica):
            return self._replicas[0].get_variables(names)
        params = jax.tree.map(np.asarray, average_states(params_per_replica))
        return [params for _ in (names or ("policy",))]

    @property
    def state(self):
        """The merged checkpoint view: the average of every replica's
        current state (identity for one replica)."""
        return average_states([r.state for r in self._replicas])

    @state.setter
    def state(self, merged):
        """Restore: broadcast a (checkpointed) merged state to all
        replicas."""
        for replica in self._replicas:
            replica.state = merged

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        """Per-replica executed step counts + averaging rounds — what
        ``result.extras['learners']`` reports."""
        if self._workers is not None:
            per_replica = [w.steps_taken for w in self._workers]
        else:
            per_replica = list(self._step_counts)
        return {"num_replicas": len(self._replicas),
                "average_period": self._period,
                "rounds": self._server.rounds,
                "per_replica_steps": per_replica}


class LearnerReplicaWorker:
    """One learner replica as a program-graph node (a run+serve hybrid like
    the single-learner node): steps SGD on its own shard's dataset until
    stopped, rendezvous at the ``ParameterServer`` every ``average_period``
    steps (``param_server=None`` skips the rendezvous — the plain
    single-learner node is the degenerate case), and serves
    ``get_variables`` for debugging/conformance.

    ``dataset`` (a ``PrefetchingDataset`` when prefetch is enabled) is
    closed on stop and on run-loop exit, so replica teardown cannot leak
    sampler threads across sequential runs in one process.
    """

    def __init__(self, learner, param_server=None, replica_id: int = 0,
                 average_period: int = 1, max_steps: Optional[int] = None,
                 dataset=None, shard=None):
        if average_period < 1:
            raise ValueError(
                f"average_period must be >= 1, got {average_period}")
        self.learner = learner
        self.param_server = param_server
        self.replica_id = replica_id
        self.average_period = average_period
        self.max_steps = max_steps
        self.dataset = dataset
        self.shard = shard
        self.steps_taken = 0
        self._stop = threading.Event()
        self._down = threading.Event()
        self._m_degraded = None

    def run(self):
        local = 0
        try:
            while True:
                if self._stop.is_set():
                    return
                if self._down.is_set():
                    # simulated death (service failover): pause until the
                    # watchdog restores this replica's state and marks it up
                    time.sleep(0.02)
                    continue
                if self.max_steps is not None \
                        and self.steps_taken >= self.max_steps:
                    return
                try:
                    self.learner.step()
                except ConnectionError:
                    if self._stop.is_set():
                        return
                    # this replica's replay shard is in its restart window:
                    # degrade (skip the step) instead of dying and burning
                    # a restart budget that belongs to real failures
                    self._degraded_metric_inc()
                    time.sleep(0.05)
                    continue
                except Exception:
                    if self._stop.is_set():
                        return
                    raise
                self.steps_taken += 1
                local += 1
                if self.param_server is not None \
                        and local >= self.average_period:
                    local = 0
                    try:
                        merged = self.param_server.sync(self.replica_id,
                                                        self.learner.state)
                    except ConnectionError:
                        if self._stop.is_set():
                            return
                        self._degraded_metric_inc()
                        continue   # keep local state; rejoin next period
                    if merged is None:   # server stopped mid-round
                        return
                    self.learner.state = merged
        finally:
            self._close_dataset()

    def stop(self):
        self._stop.set()
        # wake a step() blocked on the prefetch queue: close() sets the
        # dataset's stop event, its next() raises the "stopped" timeout,
        # and the run loop exits through the stop check above.
        self._close_dataset()

    # --------------------------------------------------- service failover
    def mark_down(self):
        """Simulate abrupt replica death: the run loop pauses (no SGD, no
        rendezvous — with quorum averaging the other replicas keep merging
        without it) until the watchdog restores and ``mark_up``s it."""
        self._down.set()

    def mark_up(self):
        self._down.clear()

    def state_dict(self) -> Dict[str, Any]:
        """Snapshot for service failover: the learner pytree (replicas swap
        it atomically, so a concurrent read is a consistent state) plus the
        step count the restart accounting resumes from."""
        return {"learner_state": self.learner.state,
                "steps_taken": self.steps_taken}

    def load_state_dict(self, state: Dict[str, Any]):
        self.learner.state = state["learner_state"]
        self.steps_taken = int(state["steps_taken"])

    def _degraded_metric_inc(self):
        if self._m_degraded is None:
            if not _telemetry.enabled():
                return
            self._m_degraded = _telemetry.counter(
                f"resilience/learner_replica_{self.replica_id}/skipped_steps")
        self._m_degraded.inc()

    def get_variables(self, names: Sequence[str] = ()):
        return self.learner.get_variables(names)

    def _close_dataset(self):
        if self.dataset is not None and hasattr(self.dataset, "close"):
            self.dataset.close()
