"""Experiment entrypoints: one config, three execution modes.

``run_experiment`` and ``run_distributed_experiment`` are symmetric: both
take an ``ExperimentConfig``, call its builder factory exactly once, and
drive the SAME builder through the single-process agent (§2.2) or the
Launchpad-lite program graph (§2.4).  ``run_offline_experiment`` drives an
offline builder (fixed dataset, no actors — §2.6).  These subsume the
hand-rolled driver loops that examples/benchmarks/tests used to write
around ``make_agent`` / ``make_distributed_agent``.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.agents.builders import make_agent, make_distributed_agent
from repro.core import (Counter, EnvironmentLoop, VariableClient,
                        make_environment_spec)
from repro.experiments.config import ExperimentConfig, ExperimentResult
from repro.telemetry import MetricsHub
from repro.telemetry import registry as _telemetry

_EVAL_SEED_OFFSET = 1_000_003


def _evaluate(config: ExperimentConfig, builder, variable_source,
              episodes: Optional[int] = None, counter=None) -> float:
    """One eval pass: a greedy actor with no adder (§4.2's evaluator)."""
    episodes = config.eval_episodes if episodes is None else episodes
    if episodes <= 0:
        return float("nan")
    env = config.environment_factory(config.seed + _EVAL_SEED_OFFSET)
    client = VariableClient(variable_source)
    actor = builder.make_actor(builder.make_policy(evaluation=True),
                               client, adder=None,
                               seed=config.seed + _EVAL_SEED_OFFSET)
    loop = EnvironmentLoop(env, actor, counter=counter, label="evaluator")
    return float(np.mean([loop.run_episode()["episode_return"]
                          for _ in range(episodes)]))


def _make_checkpointer(config: ExperimentConfig):
    if not config.checkpoint_dir:
        return None
    from repro.checkpoint import Checkpointer
    return Checkpointer(config.checkpoint_dir)


def _make_run_checkpointer(config: ExperimentConfig):
    """Run-wide checkpointer (learner + replay + counters + run state) for
    the online entrypoints; offline runs keep the plain learner-only
    ``Checkpointer`` (no replay or actors exist there)."""
    if not config.checkpoint_dir:
        return None
    from repro.resilience import RunCheckpointer
    return RunCheckpointer(config.checkpoint_dir)


def run_experiment(config: ExperimentConfig,
                   num_episodes: Optional[int] = None) -> ExperimentResult:
    """Single-process run: the env loop drives an Agent built from the
    config's builder; eval and checkpointing happen on their cadences.

    With ``num_envs_per_actor > 1`` the train loop is a
    ``VectorizedEnvironmentLoop`` over a ``VectorEnv`` — N auto-resetting
    envs, one vmapped policy dispatch per tick — run in chunks of whole
    episodes so the eval/checkpoint cadences keep their per-episode meaning.
    """
    env = config.environment_factory(config.seed)
    spec = make_environment_spec(env)
    builder = config.builder_factory(spec)
    num_envs = (config.num_envs_per_actor
                if config.num_envs_per_actor is not None
                else builder.options.num_envs_per_actor)
    agent = make_agent(builder, seed=config.seed,
                       num_replay_shards=config.num_replay_shards,
                       num_envs=num_envs,
                       num_learner_replicas=config.num_learner_replicas,
                       learner_average_period=config.learner_average_period,
                       learner_sync=config.learner_sync,
                       replay_routing=config.replay_routing,
                       telemetry=config.telemetry)
    # Single-process telemetry: no pusher thread needed — the whole run
    # lives in this process, so one final push at the end captures it all.
    telemetry_hub = (MetricsHub(jsonl_path=config.telemetry_jsonl)
                     if _telemetry.enabled() else None)
    counter = Counter()
    logger = (config.logger_factory("train")
              if config.logger_factory else None)
    if num_envs > 1:
        from repro.core import VectorizedEnvironmentLoop
        from repro.envs.vector import VectorEnv
        vector_env = VectorEnv(config.environment_factory, num_envs,
                               seed=config.seed)
        loop = VectorizedEnvironmentLoop(vector_env, agent, counter=counter,
                                         logger=logger, label="actor")
    else:
        loop = EnvironmentLoop(env, agent, counter=counter, logger=logger,
                               label="actor")
    checkpointer = _make_run_checkpointer(config)
    last_ckpt_step: Optional[int] = None

    episodes = config.num_episodes if num_episodes is None else num_episodes
    returns, steps, wall, evals = [], [], [], []
    total_steps = 0
    episodes_done = 0
    next_eval = config.eval_every or 0
    t0 = time.time()

    def _run_state():
        # Everything outside learner/replay/counter that exact resume
        # needs, captured at an episode boundary (adder buffers flushed,
        # recurrent actor state about to reinitialize at observe_first).
        state = {"agent": agent.state_dict(),
                 "bookkeeping": {
                     "returns": list(returns), "steps": list(steps),
                     "wall": list(wall), "evals": list(evals),
                     "total_steps": total_steps,
                     "episodes_done": episodes_done,
                     "next_eval": next_eval,
                     "elapsed": time.time() - t0}}
        if hasattr(loop, "state_dict"):
            state["loop"] = loop.state_dict()
        if num_envs == 1 and hasattr(env, "get_state"):
            state["env"] = env.get_state()
        return state

    def _save_run(at_step):
        checkpointer.save(at_step, agent.learner.state,
                          replay=agent.table.state_dict(),
                          counts=counter.get_counts(),
                          run_state=_run_state(),
                          meta={"mode": "single_process"})

    if config.resume and checkpointer is not None:
        snapshot = checkpointer.restore(agent.learner.state)
        if snapshot is not None:
            agent.learner.state = snapshot.learner_state
            if snapshot.replay is not None:
                agent.table.load_state_dict(snapshot.replay)
            if snapshot.counts is not None:
                counter.set_counts(snapshot.counts)
            rs = snapshot.run_state or {}
            if "agent" in rs:
                agent.load_state_dict(rs["agent"])
            if "loop" in rs and hasattr(loop, "load_state_dict"):
                loop.load_state_dict(rs["loop"])
            if rs.get("env") is not None and hasattr(env, "set_state"):
                env.set_state(rs["env"])
            book = rs.get("bookkeeping", {})
            returns[:] = book.get("returns", [])
            steps[:] = book.get("steps", [])
            wall[:] = book.get("wall", [])
            evals[:] = book.get("evals", [])
            total_steps = int(book.get("total_steps", 0))
            episodes_done = int(book.get("episodes_done", 0))
            next_eval = book.get("next_eval", next_eval)
            t0 = time.time() - float(book.get("elapsed", 0.0))
            last_ckpt_step = snapshot.step

    while episodes_done < episodes:
        if num_envs > 1:
            # chunk = one eval period (or everything left): the vectorized
            # loop returns one result per COMPLETED episode.  The step cap
            # bounds the chunk too — don't overrun max_actor_steps by a
            # whole chunk of episodes.
            chunk = min(config.eval_every or episodes - episodes_done,
                        episodes - episodes_done)
            remaining_steps = (None if config.max_actor_steps is None
                               else max(config.max_actor_steps - total_steps,
                                        1))
            chunk_results = loop.run(num_episodes=chunk,
                                     num_steps=remaining_steps)
        else:
            chunk_results = [loop.run_episode()]
        for result in chunk_results:
            total_steps += result["episode_length"]
            returns.append(result["episode_return"])
            steps.append(total_steps)
            wall.append(time.time() - t0)
        episodes_done += len(chunk_results)
        if config.eval_every and config.eval_episodes > 0 \
                and episodes_done >= next_eval:
            next_eval += config.eval_every
            evals.append((total_steps,
                          _evaluate(config, builder, agent.learner,
                                    counter=counter)))
        if checkpointer and config.checkpoint_every:
            learner_steps = int(agent.learner.state.steps)
            if learner_steps - (last_ckpt_step or 0) >= config.checkpoint_every:
                _save_run(learner_steps)
                last_ckpt_step = learner_steps
        if (config.max_actor_steps is not None
                and total_steps >= config.max_actor_steps):
            break

    # final eval — unless disabled, or a periodic eval already ran at
    # exactly this point
    if config.eval_episodes > 0 and (not evals or evals[-1][0] != total_steps):
        evals.append((total_steps,
                      _evaluate(config, builder, agent.learner,
                                counter=counter)))
    learner_steps = int(agent.learner.state.steps)
    if checkpointer and learner_steps != last_ckpt_step:
        # Deduped against the cadence checkpoint: when the last periodic
        # save already captured exactly this learner step, the final save
        # would be byte-for-byte redundant — skip it.
        _save_run(learner_steps)
    extras = {}
    learner_stats = getattr(agent.learner, "stats", None)
    if callable(learner_stats):   # MultiLearner: per-replica steps + rounds
        extras["learners"] = learner_stats()
    if telemetry_hub is not None:
        telemetry_hub.push(_telemetry.node_name(), _telemetry.snapshot())
        telemetry_hub.stop()
        extras["telemetry"] = telemetry_hub.snapshot()
    return ExperimentResult(
        train_returns=returns, actor_steps=steps, walltime=wall,
        eval_returns=evals, counts=counter.get_counts(),
        learner_steps=learner_steps, learner=agent.learner, builder=builder,
        extras=extras)


def run_distributed_experiment(config: ExperimentConfig, num_actors: int,
                               max_actor_steps: Optional[int] = None,
                               timeout_s: float = 300.0,
                               with_evaluator: bool = False,
                               poll_s: float = 0.2) -> ExperimentResult:
    """Distributed run: the SAME builder, unchanged, on the Launchpad-lite
    graph (Fig 4) — N actor nodes + learner + rate-limited replay.  The
    execution backend comes from ``config.launcher`` (``"local"`` threads or
    ``"multiprocess"`` OS processes with courier RPC edges)."""
    if num_actors < 1:
        raise ValueError(f"num_actors must be >= 1, got {num_actors}")
    spec = make_environment_spec(config.environment_factory(config.seed))
    builder = config.builder_factory(spec)
    target = (config.max_actor_steps if max_actor_steps is None
              else max_actor_steps)
    checkpointer = _make_run_checkpointer(config)

    restore = None
    if config.resume and checkpointer is not None:
        def restore(learner, table, counter):
            # Called by the assembly layer once the services exist but
            # before any worker launches: the restored learner/replay/
            # counter state is the first state anything observes.  Workers
            # then re-interleave asynchronously — same state, not the same
            # schedule (see ROADMAP "Elastic & resumable runs").
            snapshot = checkpointer.restore(learner.state)
            if snapshot is None:
                return
            learner.state = snapshot.learner_state
            if snapshot.replay is not None:
                table.load_state_dict(snapshot.replay)
            if snapshot.counts is not None:
                counter.set_counts(snapshot.counts)

    dist = make_distributed_agent(builder, config.environment_factory,
                                  num_actors=num_actors, seed=config.seed,
                                  with_evaluator=with_evaluator,
                                  num_replay_shards=config.num_replay_shards,
                                  prefetch_size=config.prefetch_size,
                                  launcher=config.launcher,
                                  builder_factory=config.builder_factory,
                                  spec=spec,
                                  num_envs_per_actor=config.num_envs_per_actor,
                                  inference=config.inference,
                                  inference_max_batch_size=(
                                      config.inference_max_batch_size),
                                  inference_max_wait_ms=(
                                      config.inference_max_wait_ms),
                                  num_learner_replicas=(
                                      config.num_learner_replicas),
                                  learner_average_period=(
                                      config.learner_average_period),
                                  telemetry=config.telemetry,
                                  telemetry_push_period_s=(
                                      config.telemetry_push_period_s),
                                  telemetry_jsonl=config.telemetry_jsonl,
                                  restart_policy=config.restart_policy,
                                  chaos=config.chaos,
                                  rpc_retry=config.rpc_retry,
                                  barrier_timeout_s=config.barrier_timeout_s,
                                  min_quorum=config.min_quorum,
                                  learner_sync=config.learner_sync,
                                  replay_routing=config.replay_routing,
                                  service_snapshot_period_s=(
                                      config.service_snapshot_period_s),
                                  restore=restore)
    last_ckpt_step: Optional[int] = None

    def _save_run(at_step, counts):
        # Services (learner, replay, counter) are parent-resident under
        # both backends, so the parent can snapshot them directly; workers
        # hold no durable state (their experience is already in replay).
        checkpointer.save(at_step, dist.learner.state,
                          replay=dist.table.state_dict(),
                          counts=counts,
                          meta={"mode": "distributed",
                                "launcher": config.launcher})

    t0 = time.time()
    try:
        while time.time() - t0 < timeout_s:
            counts = dist.counter.get_counts()
            if target is not None and counts.get("actor_steps", 0) >= target:
                break
            if checkpointer and config.checkpoint_every:
                learner_steps = int(dist.learner.state.steps)
                if learner_steps - (last_ckpt_step or 0) \
                        >= config.checkpoint_every:
                    _save_run(learner_steps, counts)
                    last_ckpt_step = learner_steps
            time.sleep(poll_s)
        counts = dist.counter.get_counts()
        rl = dist.table.rate_limiter
        extras = {
            "num_actors": num_actors,
            "launcher": config.launcher,
            "inserts": rl.inserts,
            "samples": rl.samples,
            "min_size_to_sample": rl.min_size_to_sample,
            "spi_effective": rl.samples / max(
                rl.inserts - rl.min_size_to_sample, 1),
            "walltime": time.time() - t0,
        }
        if hasattr(dist.table, "stats"):   # ShardedReplay: per-shard view
            extras["replay"] = dist.table.stats()
        if dist.inference_server is not None:
            extras["inference"] = dist.inference_server.stats()
        learner_stats = dist.learner_stats()
        if learner_stats is not None:   # multi-learner: replica steps/rounds
            extras["learners"] = learner_stats
        restart_stats = getattr(dist.launcher, "restart_stats", None)
        if callable(restart_stats):   # elastic supervisor bookkeeping
            extras["resilience"] = restart_stats()
        if with_evaluator:
            extras["evaluator_returns"] = dist.evaluator_returns()
    finally:
        dist.stop()
    # After stop(): worker processes pushed their final snapshots during
    # teardown and the parent pusher flushed post-join, so the merged view
    # covers every node's end-of-run state.
    telemetry_snapshot = dist.telemetry_snapshot()
    if telemetry_snapshot is not None:
        extras["telemetry"] = telemetry_snapshot

    total_steps = int(counts.get("actor_steps", 0))
    evals = ([(total_steps, _evaluate(config, builder, dist.learner))]
             if config.eval_episodes > 0 else [])
    learner_steps = int(dist.learner.state.steps)
    if checkpointer and learner_steps != last_ckpt_step:
        _save_run(learner_steps, counts)
    return ExperimentResult(
        train_returns=[], actor_steps=[total_steps], walltime=[extras["walltime"]],
        eval_returns=evals, counts=counts, learner_steps=learner_steps,
        learner=dist.learner, builder=builder, extras=extras)


def run_offline_experiment(config: ExperimentConfig,
                           num_learner_steps: int = 1000) -> ExperimentResult:
    """Offline run (§2.6): no actors — step the learner over the builder's
    fixed dataset, then evaluate the resulting policy."""
    spec = make_environment_spec(config.environment_factory(config.seed))
    builder = config.builder_factory(spec)
    if not builder.options.offline:
        raise ValueError(
            f"{type(builder).__name__} is not an offline builder "
            f"(options.offline is False)")
    table = builder.make_replay()
    iterator = builder.make_dataset(table)
    learner = builder.make_learner(
        iterator, priority_update_cb=table.update_priorities)
    logger = (config.logger_factory("learner")
              if config.logger_factory else None)
    checkpointer = _make_checkpointer(config)
    evals = []
    t0 = time.time()
    for step in range(num_learner_steps):
        metrics = learner.step()
        if logger:
            logger(metrics)
        if config.eval_every and config.eval_episodes > 0 \
                and (step + 1) % config.eval_every == 0:
            evals.append((step + 1, _evaluate(config, builder, learner)))
    if config.eval_episodes > 0 and (not evals
                                     or evals[-1][0] != num_learner_steps):
        evals.append((num_learner_steps, _evaluate(config, builder, learner)))
    learner_steps = int(learner.state.steps)
    if checkpointer:
        checkpointer.save(learner.state, learner_steps)
    return ExperimentResult(
        train_returns=[], actor_steps=[], walltime=[time.time() - t0],
        eval_returns=evals, counts={}, learner_steps=learner_steps,
        learner=learner, builder=builder,
        extras={"dataset_size": table.size()})
