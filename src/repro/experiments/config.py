"""Experiment configuration: everything needed to reproduce a run.

An ``ExperimentConfig`` is the single declarative object from which both
``run_experiment`` (single-process, §2.2) and ``run_distributed_experiment``
(Launchpad-lite program, §2.4) construct the SAME agent — the builder is
shared unchanged between the two execution modes, which is the paper's
central modularity claim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.builders import AgentBuilder
from repro.core.types import Environment, EnvironmentSpec

BuilderFactory = Callable[[EnvironmentSpec], AgentBuilder]
EnvironmentFactory = Callable[[int], Environment]
LoggerFactory = Callable[[str], Callable[[Dict[str, Any]], None]]


@dataclasses.dataclass
class ExperimentConfig:
    """Declarative description of a training run.

    builder_factory: spec -> AgentBuilder (called once per run).
    environment_factory: seed -> Environment (called per actor/evaluator).
    seed: base RNG seed; actors and evaluators derive offsets from it.
    num_episodes: training episodes (single-process runs).
    max_actor_steps: stop once the shared actor-step counter passes this
        (distributed runs; optional cap for single-process runs).
    logger_factory: label -> logger callable, attached to the train loop.
    checkpoint_dir: if set, learner state is checkpointed there.
    checkpoint_every: learner steps between checkpoints (0 = only final).
    eval_every: run an eval pass every N training episodes (0 = only final).
    eval_episodes: episodes per eval pass.
    num_replay_shards: replay shards built from the builder's
        ``make_replay`` (None = defer to the builder's options; >1 = a
        ``ShardedReplay`` service, one replay node per shard in the
        distributed program graph).
    prefetch_size: learner prefetch queue depth in batches (None = defer to
        the builder's options; >0 = a ``PrefetchingDataset`` on the
        distributed learner hot path).
    launcher: execution backend for distributed runs, resolved through the
        ``repro.distributed`` launcher registry — ``"local"`` (worker nodes
        on threads) or ``"multiprocess"`` (each worker node in its own OS
        process with courier RPC edges; requires ``builder_factory`` and
        ``environment_factory`` to be picklable, i.e. module-level).
    num_envs_per_actor: environments per actor (None = defer to the
        builder's options; N > 1 = each actor is a ``VectorEnv`` + batched
        actor evaluating ONE vmapped policy call per N env transitions —
        single-process and distributed runs alike).
    inference: policy-evaluation placement for distributed runs (None =
        defer to the builder's options) — ``"local"`` (each actor holds its
        own policy copy) or ``"server"`` (SEED-style: one ``InferenceServer``
        service node coalesces ``select_action`` RPCs from every actor
        worker into batched forward passes).  Single-process runs always
        evaluate locally.
    inference_max_batch_size: the server's coalescing window in observation
        ROWS per forward pass (None = one full fleet sweep,
        ``num_actors * num_envs_per_actor``; ``num_envs_per_actor`` disables
        coalescing — every request dispatches alone).
    inference_max_wait_ms: how long the server holds an open window for
        more requests, measured from the window's first request.
    num_learner_replicas: learner replicas built from the builder's
        ``make_learner`` (None = defer to the builder's options).  With
        N > 1 each replica consumes its own replay shard's dataset
        (``num_replay_shards`` must be unset or equal to N) and a
        ``ParameterServer`` periodically averages replica params/opt-state;
        actors, evaluators, and checkpoints still see ONE logical learner.
        Setting this explicitly — even to 1 — routes the run through the
        multi-learner machinery, which is exactly equivalent to the plain
        single-learner path at N=1 (the parity the test net proves).
    learner_average_period: per-replica SGD steps between parameter-
        averaging rounds (None = defer to the builder's options).
    telemetry: enable the ``repro.telemetry`` layer (None = defer to the
        builder's options).  When on, every worker process records hot-path
        metrics (courier RPC latency/bytes, inference queue-wait and batch
        occupancy, replay block times and occupancy, barrier waits) and
        pushes periodic snapshots to a run-wide ``MetricsHub``; the merged
        snapshot is returned in ``ExperimentResult.extras["telemetry"]``.
    telemetry_push_period_s: seconds between worker snapshot pushes (None =
        defer to the builder's options).
    telemetry_jsonl: if set, the hub appends every received snapshot to
        this JSONL file (one ``{node, time, metrics}`` record per push).
    resume: restore the run from ``checkpoint_dir``'s latest run-wide
        snapshot (learner + replay contents + counters + RNG streams) and
        continue.  Single-process runs resume bit-exactly; distributed
        runs restore the same state but re-interleave asynchronously (see
        ROADMAP "Elastic & resumable runs").  No snapshot present = start
        fresh.  Requires ``checkpoint_dir``.
    restart_policy: a ``repro.resilience.RestartPolicy`` enabling elastic
        actor pools under the multiprocess launcher — dead ``role="worker"``
        replicas are classified (crash / preemption / shutdown) and
        respawned with exponential backoff under a per-worker budget,
        instead of failing the run.  None = fail-fast (the default).
    chaos: a ``repro.resilience.ChaosPolicy`` injecting seeded faults
        (worker kills after N steps, service kills by activity, courier
        RPC delay/drop) into distributed runs — the harness the chaos
        acceptance tests drive.  None = no injection.
    rpc_retry: a ``repro.distributed.RetryConfig`` tuning courier
        client-side retry/backoff — how long calls reconnect through a
        service's restart window before raising ``ServiceUnavailable``,
        and how many attempts idempotent methods get when a response is
        lost.  Installed process-globally in every worker.  None = the
        courier defaults.
    barrier_timeout_s: parameter-server quorum mode — a round whose first
        contribution is this old merges whatever >= ``min_quorum``
        replicas delivered instead of stalling on stragglers.  None (the
        default) keeps the strict all-or-nothing barrier.
    min_quorum: minimum replica contributions for a timed-out round to
        merge (None with ``barrier_timeout_s`` set = 1).  Requires
        ``barrier_timeout_s``.
    learner_sync: how learner replicas exchange parameters (None = defer
        to the builder's options, whose default is ``"barrier"``) —
        ``"barrier"`` (strict all-or-nothing rendezvous), ``"quorum"``
        (barrier + ``barrier_timeout_s``/``min_quorum``), or ``"async"``
        (push/pull ``AsyncParameterService``: replicas push at their own
        cadence and pull the latest staleness-weighted blend, never
        waiting for peers).  ``"async"`` engages the multi-learner
        machinery even at one replica — the 1-replica parity case — and
        is incompatible with the quorum knobs.
    replay_routing: insert routing across replay shards (None = defer to
        the builder's options) — ``"round_robin"``, ``"hash"``, or
        ``"affinity"`` (vectorized actors write each env's stream
        straight to its assigned shard through per-env ``ShardWriter``s;
        priority updates route back by key).
    service_snapshot_period_s: cadence at which the service watchdog
        snapshots recoverable services for failover (None = 0.5s).  Only
        meaningful with ``restart_policy`` under the multiprocess
        launcher.
    """

    builder_factory: BuilderFactory
    environment_factory: EnvironmentFactory
    seed: int = 0
    num_episodes: int = 100
    max_actor_steps: Optional[int] = None
    logger_factory: Optional[LoggerFactory] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    eval_every: int = 0
    eval_episodes: int = 10
    num_replay_shards: Optional[int] = None
    prefetch_size: Optional[int] = None
    launcher: str = "local"
    num_envs_per_actor: Optional[int] = None
    inference: Optional[str] = None
    inference_max_batch_size: Optional[int] = None
    inference_max_wait_ms: float = 2.0
    num_learner_replicas: Optional[int] = None
    learner_average_period: Optional[int] = None
    telemetry: Optional[bool] = None
    telemetry_push_period_s: Optional[float] = None
    telemetry_jsonl: Optional[str] = None
    resume: bool = False
    restart_policy: Optional[Any] = None
    chaos: Optional[Any] = None
    rpc_retry: Optional[Any] = None
    barrier_timeout_s: Optional[float] = None
    min_quorum: Optional[int] = None
    learner_sync: Optional[str] = None
    replay_routing: Optional[str] = None
    service_snapshot_period_s: Optional[float] = None

    def __post_init__(self):
        if self.num_episodes < 1:
            raise ValueError(f"num_episodes must be >= 1, "
                             f"got {self.num_episodes}")
        if self.eval_every < 0 or self.eval_episodes < 0:
            raise ValueError("eval cadence values must be >= 0")
        if self.checkpoint_every < 0:
            raise ValueError(f"checkpoint_every must be >= 0, "
                             f"got {self.checkpoint_every}")
        if self.num_replay_shards is not None and self.num_replay_shards < 1:
            raise ValueError(f"num_replay_shards must be >= 1, "
                             f"got {self.num_replay_shards}")
        if self.prefetch_size is not None and self.prefetch_size < 0:
            raise ValueError(f"prefetch_size must be >= 0, "
                             f"got {self.prefetch_size}")
        if not self.launcher or not isinstance(self.launcher, str):
            raise ValueError(f"launcher must be a backend name, "
                             f"got {self.launcher!r}")
        if self.num_envs_per_actor is not None \
                and self.num_envs_per_actor < 1:
            raise ValueError(f"num_envs_per_actor must be >= 1, "
                             f"got {self.num_envs_per_actor}")
        if self.inference is not None \
                and self.inference not in ("local", "server"):
            raise ValueError(f"inference must be 'local' or 'server', "
                             f"got {self.inference!r}")
        if self.inference_max_batch_size is not None \
                and self.inference_max_batch_size < 1:
            raise ValueError(f"inference_max_batch_size must be >= 1, "
                             f"got {self.inference_max_batch_size}")
        if self.inference_max_wait_ms < 0:
            raise ValueError(f"inference_max_wait_ms must be >= 0, "
                             f"got {self.inference_max_wait_ms}")
        if self.num_learner_replicas is not None \
                and self.num_learner_replicas < 1:
            raise ValueError(f"num_learner_replicas must be >= 1, "
                             f"got {self.num_learner_replicas}")
        if self.learner_average_period is not None \
                and self.learner_average_period < 1:
            raise ValueError(f"learner_average_period must be >= 1, "
                             f"got {self.learner_average_period}")
        if self.telemetry_push_period_s is not None \
                and self.telemetry_push_period_s <= 0:
            raise ValueError(f"telemetry_push_period_s must be > 0, "
                             f"got {self.telemetry_push_period_s}")
        if self.resume and not self.checkpoint_dir:
            raise ValueError("resume=True requires checkpoint_dir")
        if self.restart_policy is not None:
            from repro.resilience import RestartPolicy
            if not isinstance(self.restart_policy, RestartPolicy):
                raise ValueError(f"restart_policy must be a RestartPolicy, "
                                 f"got {self.restart_policy!r}")
        if self.chaos is not None:
            from repro.resilience import ChaosPolicy
            if not isinstance(self.chaos, ChaosPolicy):
                raise ValueError(f"chaos must be a ChaosPolicy, "
                                 f"got {self.chaos!r}")
        if self.rpc_retry is not None:
            from repro.distributed import RetryConfig
            if not isinstance(self.rpc_retry, RetryConfig):
                raise ValueError(f"rpc_retry must be a RetryConfig, "
                                 f"got {self.rpc_retry!r}")
        if self.barrier_timeout_s is not None and self.barrier_timeout_s <= 0:
            raise ValueError(f"barrier_timeout_s must be > 0, "
                             f"got {self.barrier_timeout_s}")
        if self.min_quorum is not None:
            if self.barrier_timeout_s is None:
                raise ValueError(
                    "min_quorum requires barrier_timeout_s (a round only "
                    "closes below full strength when the barrier times out)")
            if self.min_quorum < 1:
                raise ValueError(f"min_quorum must be >= 1, "
                                 f"got {self.min_quorum}")
        if self.learner_sync is not None:
            if self.learner_sync not in ("barrier", "quorum", "async"):
                raise ValueError(
                    f"learner_sync must be 'barrier', 'quorum' or 'async', "
                    f"got {self.learner_sync!r}")
            if self.learner_sync == "quorum" \
                    and self.barrier_timeout_s is None:
                raise ValueError(
                    "learner_sync='quorum' requires barrier_timeout_s "
                    "(the timeout is what lets a round close below full "
                    "strength)")
            if self.learner_sync == "async" and (
                    self.barrier_timeout_s is not None
                    or self.min_quorum is not None):
                raise ValueError(
                    "learner_sync='async' is incompatible with "
                    "barrier_timeout_s/min_quorum: async replicas never "
                    "rendezvous, so there is no round to time out")
        if self.replay_routing is not None \
                and self.replay_routing not in ("round_robin", "hash",
                                                "affinity"):
            raise ValueError(
                f"replay_routing must be 'round_robin', 'hash' or "
                f"'affinity', got {self.replay_routing!r}")
        if self.service_snapshot_period_s is not None \
                and self.service_snapshot_period_s <= 0:
            raise ValueError(f"service_snapshot_period_s must be > 0, "
                             f"got {self.service_snapshot_period_s}")


@dataclasses.dataclass
class ExperimentResult:
    """What a run hands back: curves, eval points, and the live learner."""

    train_returns: List[float]
    actor_steps: List[int]
    walltime: List[float]
    # (progress, mean_return): progress is actor steps for online runs,
    # learner steps for offline runs (no actors exist there).
    eval_returns: List[Tuple[int, float]]
    counts: Dict[str, float]
    learner_steps: int
    learner: Any
    builder: AgentBuilder
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def final_eval_return(self) -> Optional[float]:
        return self.eval_returns[-1][1] if self.eval_returns else None
