"""Experiments layer: config-driven runs over the AgentBuilder protocol.

The single way examples, benchmarks, and tests construct agents:

    config = ExperimentConfig(builder_factory=..., environment_factory=...)
    result = run_experiment(config)                        # §2.2
    result = run_distributed_experiment(config, num_actors=4)   # §2.4
    result = run_offline_experiment(config, num_learner_steps=500)  # §2.6
"""
from repro.experiments.config import (  # noqa: F401
    ExperimentConfig, ExperimentResult)
from repro.experiments.run import (  # noqa: F401
    run_distributed_experiment, run_experiment, run_offline_experiment)
