from repro.checkpoint.checkpointer import (Checkpointer,  # noqa: F401
                                           CheckpointError, fsync_directory)
