"""Pytree checkpointing (npz): learner state + counters persist through
interruptions; learner walltime is checkpointed alongside the networks so
timekeeping survives preemption (§4.2)."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}, treedef


class Checkpointer:
    def __init__(self, directory: str, name: str = "checkpoint",
                 keep: int = 3):
        self.directory = directory
        self.name = name
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"{self.name}_{step}.npz")

    def save(self, state, step: int, metadata: Optional[Dict] = None):
        arrays, treedef = _flatten(state)
        meta = dict(metadata or {})
        meta["step"] = step
        # atomic write
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        os.close(fd)
        np.savez(tmp, __meta__=json.dumps(meta), **arrays)
        src = tmp + ".npz"          # np.savez appends .npz
        os.replace(src, self._path(step))
        if os.path.exists(tmp):
            os.unlink(tmp)
        self._gc()

    def _gc(self):
        ckpts = self.list_steps()
        for step in ckpts[:-self.keep]:
            os.unlink(self._path(step))

    def list_steps(self):
        steps = []
        for f in os.listdir(self.directory):
            if f.startswith(self.name + "_") and f.endswith(".npz"):
                try:
                    steps.append(int(f[len(self.name) + 1:-4]))
                except ValueError:
                    pass
        return sorted(steps)

    def restore(self, state_template, step: Optional[int] = None):
        """Returns (state, metadata) or (None, None) if nothing saved."""
        steps = self.list_steps()
        if not steps:
            return None, None
        step = steps[-1] if step is None else step
        with np.load(self._path(step), allow_pickle=False) as data:
            meta = json.loads(str(data["__meta__"]))
            leaves, treedef = jax.tree_util.tree_flatten(state_template)
            restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
            state = jax.tree_util.tree_unflatten(treedef, restored)
        return state, meta
