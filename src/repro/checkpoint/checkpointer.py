"""Pytree checkpointing (npz): learner state + counters persist through
interruptions; learner walltime is checkpointed alongside the networks so
timekeeping survives preemption (§4.2).

Crash-consistency contract: ``save`` publishes a ``<name>_latest.json``
manifest (atomic replace + directory fsync) *after* the npz itself is in
place and *before* garbage collection, so a crash at any point leaves
``restore()`` pointing at a fully written step — never at a half-collected
or half-written one.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint exists but cannot be restored into the given template
    (leaf count or leaf shape mismatch, or a manifest pointing at a missing
    file)."""


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}, treedef


def fsync_directory(directory: str):
    """Flush directory metadata (renames) to disk; no-op where unsupported."""
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class Checkpointer:
    def __init__(self, directory: str, name: str = "checkpoint",
                 keep: int = 3):
        self.directory = directory
        self.name = name
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"{self.name}_{step}.npz")

    def _manifest_path(self) -> str:
        return os.path.join(self.directory, f"{self.name}_latest.json")

    def save(self, state, step: int, metadata: Optional[Dict] = None):
        arrays, treedef = _flatten(state)
        meta = dict(metadata or {})
        meta["step"] = step
        # atomic write
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        os.close(fd)
        np.savez(tmp, __meta__=json.dumps(meta), **arrays)
        src = tmp + ".npz"          # np.savez appends .npz
        os.replace(src, self._path(step))
        if os.path.exists(tmp):
            os.unlink(tmp)
        # Publish the manifest before gc: if we crash mid-collection,
        # restore() still resolves to this (complete) step rather than
        # scanning a directory that gc may have half-emptied.
        self._write_manifest(step)
        fsync_directory(self.directory)
        self._gc()

    def _write_manifest(self, step: int):
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".json.tmp")
        with os.fdopen(fd, "w") as f:
            json.dump({"step": step,
                       "file": os.path.basename(self._path(step))}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._manifest_path())

    def latest_step(self) -> Optional[int]:
        """The manifest's step if present (crash-safe), else the newest
        on-disk step, else None."""
        try:
            with open(self._manifest_path()) as f:
                manifest = json.load(f)
            step = int(manifest["step"])
        except (OSError, ValueError, KeyError):
            steps = self.list_steps()
            return steps[-1] if steps else None
        if not os.path.exists(self._path(step)):
            raise CheckpointError(
                f"manifest {self._manifest_path()} points at step {step} "
                f"but {self._path(step)} is missing")
        return step

    def _gc(self):
        ckpts = self.list_steps()
        keep = ckpts[-self.keep:]
        latest = None
        try:
            latest = self.latest_step()
        except CheckpointError:
            pass
        for step in ckpts:
            if step not in keep and step != latest:
                os.unlink(self._path(step))

    def list_steps(self):
        steps = []
        for f in os.listdir(self.directory):
            if f.startswith(self.name + "_") and f.endswith(".npz"):
                try:
                    steps.append(int(f[len(self.name) + 1:-4]))
                except ValueError:
                    pass
        return sorted(steps)

    def restore(self, state_template, step: Optional[int] = None):
        """Returns (state, metadata) or (None, None) if nothing saved.

        Raises ``CheckpointError`` when the checkpoint's leaf count or any
        leaf's shape does not match ``state_template`` — a clear signal the
        network/optimizer architecture drifted from the saved run.
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                return None, None
        path = self._path(step)
        if not os.path.exists(path):
            raise CheckpointError(f"no checkpoint at step {step}: {path}")
        with np.load(path, allow_pickle=False) as data:
            meta = json.loads(str(data["__meta__"]))
            leaves, treedef = jax.tree_util.tree_flatten(state_template)
            saved = sum(1 for k in data.files if k.startswith("leaf_"))
            if saved != len(leaves):
                raise CheckpointError(
                    f"checkpoint {os.path.basename(path)} has {saved} "
                    f"leaves but the template has {len(leaves)} — the "
                    "state structure changed since this checkpoint was "
                    "written")
            restored = []
            for i, leaf in enumerate(leaves):
                arr = data[f"leaf_{i}"]
                want = np.shape(leaf)
                if tuple(arr.shape) != tuple(want):
                    raise CheckpointError(
                        f"checkpoint {os.path.basename(path)} leaf_{i} has "
                        f"shape {tuple(arr.shape)} but the template expects "
                        f"{tuple(want)}")
                restored.append(arr)
            state = jax.tree_util.tree_unflatten(treedef, restored)
        return state, meta
