"""Pluggable launcher backends for the Program graph (§2.4).

A ``Launcher`` turns a declared ``Program`` into running nodes.  The
protocol is four calls — ``launch`` / ``stop`` / ``join`` / ``should_stop``
— plus ``serve`` (export a node over courier RPC).  Backends register under
a name (``register_launcher``) and are selected with ``get_launcher``;
``ExperimentConfig.launcher`` flows that name through
``run_distributed_experiment`` so the same agent graph runs on either
backend with zero agent-side edits:

- ``"local"``   — every node in this process; workers (and runnable
  services, e.g. the learner) on threads.  Zero-overhead edges.
- ``"multiprocess"`` — each worker node in its own OS process (spawn
  context).  Service nodes stay in the parent wrapped in courier servers;
  pickling a worker's arguments converts its ``Handle`` edges into
  ``RemoteHandle`` RPC stubs bound to those servers.

Shared semantics (the launcher conformance suite in
``tests/test_distributed.py`` enforces these for every backend):

- **Fail-fast**: the first worker failure stops every sibling node; all
  failures are aggregated into ``WorkerErrors`` (a single failure re-raises
  as itself).
- **Shutdown-noise classification**: errors raised after the user requested
  shutdown — and rate-limiter wakeups caused by stopping replay tables
  (``RateLimiterTimeout``, whether raised in-process or carried back over
  courier) — are suppressed, not surfaced.
- **Join timeout**: ``join(timeout)`` that expires with nodes still running
  raises ``JoinTimeout`` naming them (folded into ``WorkerErrors`` when
  real failures were also collected) instead of returning silently.
- ``stop``/``join`` are idempotent.

Registering a new backend::

    class FleetLauncher(LauncherBase):
        backend = "fleet"
        requires_pickling = True      # node args must survive pickling
        def launch(self): ...
    register_launcher("fleet", FleetLauncher)
"""
from __future__ import annotations

import abc
import pickle
import sys
import threading
import time
from typing import Dict, List, Optional, Type

from repro.distributed.courier import RemoteHandle, Server
from repro.distributed.program import Node, Program
from repro.resilience.chaos import RESTARTS_ENV
from repro.resilience.supervisor import classify_exit
from repro.telemetry import registry as _telemetry


class WorkerErrors(RuntimeError):
    """Aggregate of every worker failure in a launched program (3.10-era
    stand-in for ExceptionGroup) — no error is silently dropped."""

    def __init__(self, errors: List[BaseException]):
        self.errors = list(errors)
        summary = "; ".join(f"[{i}] {type(e).__name__}: {e}"
                            for i, e in enumerate(self.errors))
        super().__init__(
            f"{len(self.errors)} worker(s) failed: {summary}")


class JoinTimeout(RuntimeError):
    """``join(timeout)`` expired while nodes were still running."""

    def __init__(self, node_names: List[str], timeout: Optional[float]):
        self.node_names = list(node_names)
        self.timeout = timeout
        super().__init__(
            f"join(timeout={timeout}) expired with {len(self.node_names)} "
            f"node(s) still running: {', '.join(self.node_names)}")


class Launcher(abc.ABC):
    """The backend protocol every launcher implements."""

    backend: str = ""
    # Whether worker-node factories/args must survive pickling (process- or
    # host-crossing backends).  Assembly layers use this to decide between
    # sharing rich in-memory objects and shipping picklable factories.
    requires_pickling: bool = False

    @abc.abstractmethod
    def launch(self) -> "Launcher":
        """Start every node; returns self."""

    @abc.abstractmethod
    def stop(self):
        """Request shutdown of every node (user-initiated, idempotent)."""

    @abc.abstractmethod
    def join(self, timeout: Optional[float] = None):
        """Wait for all nodes; raise collected failures / ``JoinTimeout``."""

    @abc.abstractmethod
    def should_stop(self) -> bool:
        """True once a stop (user- or fail-fast-initiated) is in flight."""


class LauncherBase(Launcher):
    """Shared machinery: parent-side node threads, fail-fast error
    collection, shutdown-noise classification, courier serving, join."""

    def __init__(self, program: Program):
        self.program = program
        self.threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self._user_stopped = False
        self._errors: List[BaseException] = []
        self._errors_lock = threading.Lock()
        self._servers: Dict[str, Server] = {}

    # ------------------------------------------------------------- courier
    def serve(self, name: str) -> RemoteHandle:
        """Export node ``name`` over a courier server (idempotent) and
        return a picklable ``RemoteHandle`` to it."""
        if name not in self._servers:
            node = self.program.node(name)
            instance = self.program.resolve(name)
            server = Server(instance, interface=node.interface,
                            name=name).start()
            self._servers[name] = server
            self.program.bind_courier(name, server.address, server.authkey)
        server = self._servers[name]
        return RemoteHandle(server.address, name=name,
                            interface=server.interface,
                            authkey=server.authkey)

    def _close_servers(self):
        for server in self._servers.values():
            server.stop()

    # ------------------------------------------------------- parent threads
    def _runs_in_parent_thread(self, node: Node) -> bool:
        """Workers run on parent threads by default; so do services whose
        instance has a run loop (the learner: steps SGD *and* serves)."""
        if node.is_worker:
            return True
        return callable(getattr(node.instance, "run", None))

    def _start_parent_thread(self, node: Node):
        node.placement = "thread"
        t = threading.Thread(target=self._run_node, args=(node,),
                             name=node.name, daemon=True)
        self.threads.append(t)
        t.start()

    def _run_node(self, node: Node):
        try:
            node.instance.run()
        except StopIteration:
            pass
        except Exception as e:
            if self._classify_as_shutdown_noise(e):
                return
            self._record_error(e)

    def _classify_as_shutdown_noise(self, e: BaseException) -> bool:
        """Once a stop is in flight (user- or fail-fast-initiated — the flag
        is always set before any table is stopped), rate-limiter wakeups are
        shutdown noise, as are connection teardowns (a stopped
        ``InferenceServer`` wakes blocked ``select_action`` callers with
        ``CourierClosed`` — mirroring the child-side classifier) and
        anything raised after the user asked us to shut down.  A "stopped"
        error with no stop in flight is a real worker death and must be
        surfaced."""
        from repro.replay.rate_limiter import RateLimiterTimeout
        return self._stop.is_set() and (
            self._user_stopped
            or isinstance(e, (RateLimiterTimeout, ConnectionError)))

    def _record_error(self, e: BaseException):
        with self._errors_lock:
            self._errors.append(e)
        # fail fast: stop the siblings so join() returns promptly
        self._initiate_stop()

    # ---------------------------------------------------------------- stop
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def _initiate_stop(self):
        self._stop.set()
        for node in self.program.nodes:
            inst = node.instance
            if inst is not None and hasattr(inst, "stop"):
                try:
                    inst.stop()
                except Exception:
                    pass

    def stop(self):
        self._user_stopped = True
        self._initiate_stop()

    # ---------------------------------------------------------------- join
    def _join_runners(self, deadline: Optional[float]):
        for t in self.threads:
            remaining = (None if deadline is None
                         else max(deadline - time.time(), 0))
            t.join(remaining)

    def _alive_nodes(self) -> List[str]:
        return [t.name for t in self.threads if t.is_alive()]

    def _reap_stragglers(self, names: List[str]):
        """Forcibly clean up nodes that survived the join timeout (threads
        cannot be killed — they are daemonic — but process backends
        override this to terminate children)."""

    def join(self, timeout: Optional[float] = None):
        deadline = None if timeout is None else time.time() + timeout
        self._join_runners(deadline)
        with self._errors_lock:
            errors = list(self._errors)
        alive = self._alive_nodes()
        if alive:
            # do not leak: the stragglers are reaped (where possible) and
            # reported by name — a retried join() then returns cleanly.
            errors.append(JoinTimeout(alive, timeout))
            self._reap_stragglers(alive)
        self._close_servers()
        if len(errors) == 1:
            raise errors[0]
        if errors:
            raise WorkerErrors(errors)


class LocalLauncher(LauncherBase):
    """Every node in this process: the single-machine backend.

    Workers (and runnable services) run on daemon threads; edges stay
    in-memory ``Handle``s — zero serialization, zero RPC overhead.
    """

    backend = "local"
    requires_pickling = False

    def launch(self) -> "LocalLauncher":
        # construct everything first (resolves the graph edges)
        for node in self.program.nodes:
            self.program.resolve(node.name)
        for node in self.program.nodes:
            if self._runs_in_parent_thread(node):
                self._start_parent_thread(node)
        return self


def _child_watch_stop(control_pipe, instance, flags):
    """Wait for the parent's stop message and relay it to the node.

    A pipe, not a shared multiprocessing.Event: a child dying mid-wait on a
    shared Event corrupts its Condition handshake and deadlocks the parent's
    set(); a dead pipe end just raises EOFError.  Parent death reads as a
    (user-style) stop so orphans shut down quietly.
    """
    try:
        msg = control_pipe.recv()
        user = bool(msg[1]) if isinstance(msg, tuple) and len(msg) > 1 \
            else False
    except (EOFError, OSError):
        user = True
    flags["user"] = flags["user"] or user
    flags["stop"] = True
    if hasattr(instance, "stop"):
        try:
            instance.stop()
        except Exception:
            pass


def _child_classify_noise(e, flags) -> bool:
    """Child-side mirror of the parent's shutdown-noise classification.
    Courier re-raises remote exceptions with their original type, so a
    ``RateLimiterTimeout`` from a parent-hosted replay table classifies
    identically here; connection teardown during shutdown is also noise."""
    from repro.replay.rate_limiter import RateLimiterTimeout
    if isinstance(e, (RateLimiterTimeout, ConnectionError)) \
            and not flags["stop"]:
        # the stop message may still be in flight on the control pipe while
        # the stopped table's wakeup raced ahead over courier — give the
        # watcher a beat before declaring a real worker death.
        deadline = time.time() + 1.0
        while not flags["stop"] and time.time() < deadline:
            time.sleep(0.02)
    if not flags["stop"]:
        return False
    return (flags["user"]
            or isinstance(e, (RateLimiterTimeout, ConnectionError)))


def _child_error(e: BaseException) -> BaseException:
    """Make a child exception safe to ship through the error queue (same
    round-trip-or-wrap policy as the courier server)."""
    from repro.distributed.courier import picklable_error
    return picklable_error(e)


def _child_main(node_name, payload, control_pipe, error_queue, restarts=0):
    """Entry point of a spawned worker process: rebuild the node from its
    pickled (factory, args, kwargs) — Handles arrive as RemoteHandles — and
    drive its run loop until done or stopped.

    ``restarts`` counts how many times this worker has been respawned by
    the elastic supervisor; it is published via ``RESTARTS_ENV`` before the
    node is built so chaos kill schedules can disarm after ``max_kills``.
    """
    import faulthandler
    import os
    import signal
    import sys
    # SIGUSR1 dumps every thread's stack to stderr — the only way to see
    # where a live worker is stuck from outside (hangs, chaos debugging).
    faulthandler.register(signal.SIGUSR1, all_threads=True)
    os.environ[RESTARTS_ENV] = str(restarts)
    flags = {"stop": False, "user": False}
    try:
        factory, args, kwargs = pickle.loads(payload)
        instance = factory(*args, **kwargs)
    except Exception as e:   # constructor failure is a worker failure
        error_queue.put((node_name, _child_error(e)))
        sys.exit(1)
    threading.Thread(target=_child_watch_stop,
                     args=(control_pipe, instance, flags),
                     daemon=True).start()
    try:
        instance.run()
    except StopIteration:
        pass
    except Exception as e:
        if _child_classify_noise(e, flags):
            sys.exit(0)
        error_queue.put((node_name, _child_error(e)))
        sys.exit(1)


class MultiprocessLauncher(LauncherBase):
    """Each worker node in its own OS process (spawn context).

    Service nodes are resolved in the parent and exported over courier;
    pickling a worker's arguments rewrites its ``Handle`` edges into
    ``RemoteHandle`` stubs bound to those servers (``Handle.__reduce__``),
    so node code is byte-identical across backends.  Child failures flow
    back through an error queue into the parent's fail-fast stop, with the
    same ``WorkerErrors`` aggregation and shutdown-noise rules as
    ``LocalLauncher``.
    """

    backend = "multiprocess"
    requires_pickling = True

    def __init__(self, program: Program):
        super().__init__(program)
        import multiprocessing
        self._ctx = multiprocessing.get_context("spawn")
        self._error_queue = self._ctx.Queue()
        self.processes: Dict[str, object] = {}
        self._control_pipes: Dict[str, object] = {}
        self._reported: set = set()
        self._monitor_thread: Optional[threading.Thread] = None
        # --- elastic supervision (repro.resilience) -------------------
        # When the program carries a RestartPolicy, dead workers are
        # respawned from their stored spawn payloads instead of failing
        # the run: deaths are classified (crash/preempted/shutdown),
        # restarts are budgeted per worker with exponential backoff.
        self._policy = getattr(program, "restart_policy", None)
        self._payloads: Dict[str, bytes] = {}
        self._restarts: Dict[str, int] = {}
        self._exit_kinds: Dict[str, List[str]] = {}
        self._respawn_at: Dict[str, float] = {}
        self._stashed: Dict[str, BaseException] = {}
        self._m_restarts = None
        # Parent-side failover for role="service" nodes (periodic snapshot,
        # kill classification, budgeted restore + courier re-bind); started
        # by launch() when the program carries a RestartPolicy.
        self._watchdog = None

    def restart_stats(self) -> Dict:
        """Supervisor bookkeeping: per-worker restart counts and the
        classification of every death observed, plus the service watchdog's
        own restore accounting."""
        stats = {"restarts": dict(self._restarts),
                 "exit_kinds": {k: list(v)
                                for k, v in self._exit_kinds.items()}}
        if self._watchdog is not None:
            stats.update(self._watchdog.stats())
        else:
            stats["service_restarts"] = {}
            stats["service_exit_kinds"] = {}
        return stats

    def launch(self) -> "MultiprocessLauncher":
        try:
            # 1. services live in the parent, exported over courier.
            for node in self.program.nodes:
                if node.role == "service":
                    self.serve(node.name)
            # 2. runnable services (the learner) get parent threads.
            for node in self.program.nodes:
                if node.role == "service" \
                        and self._runs_in_parent_thread(node):
                    self._start_parent_thread(node)
            # 2b. with a RestartPolicy, services get failover too: the
            # watchdog snapshots every recoverable service and restores
            # killed ones at the same courier address.
            if self._policy is not None:
                from repro.resilience.failover import ServiceWatchdog
                self._watchdog = ServiceWatchdog(
                    self, self._policy,
                    chaos=getattr(self.program, "chaos_policy", None),
                    snapshot_period_s=getattr(
                        self.program, "service_snapshot_period_s", 0.5))
                for node in self.program.nodes:
                    if node.role == "service":
                        self._watchdog.register(node.name, node.instance)
                self._watchdog.start()
            # 3. workers spawn as OS processes; pickling converts Handles.
            for node in self.program.nodes:
                if not node.is_worker:
                    continue
                node.placement = "process"
                try:
                    payload = pickle.dumps(
                        (node.factory, node.args, node.kwargs),
                        protocol=pickle.HIGHEST_PROTOCOL)
                except Exception as e:
                    raise RuntimeError(
                        f"worker node {node.name!r} cannot be placed in a "
                        f"child process: its factory/arguments failed to "
                        f"pickle ({type(e).__name__}: {e}). Use module-level "
                        f"factories and pass services as Handles.") from e
                self._payloads[node.name] = payload
                self._spawn(node.name, restarts=0)
        except BaseException:
            # a half-launched program must not leak: children already
            # spawned would keep training against it for the parent's
            # lifetime, and the courier servers would hold their sockets.
            self._abort_launch()
            raise
        self._monitor_thread = threading.Thread(target=self._monitor,
                                                name="launcher/monitor",
                                                daemon=True)
        self._monitor_thread.start()
        return self

    def _spawn(self, name: str, restarts: int):
        """Start (or restart) worker ``name`` from its stored payload."""
        parent_end, child_end = self._ctx.Pipe()
        old_pipe = self._control_pipes.get(name)
        self._control_pipes[name] = parent_end
        if old_pipe is not None:
            try:
                old_pipe.close()
            except OSError:
                pass
        proc = self._ctx.Process(
            target=_child_main,
            args=(name, self._payloads[name], child_end,
                  self._error_queue, restarts),
            name=name, daemon=True)
        self.processes[name] = proc
        proc.start()
        child_end.close()   # parent keeps only its own end
        # A stop initiated between scheduling and spawning would have
        # missed this pipe: relay it so the fresh child shuts down too.
        if self._stop.is_set():
            try:
                parent_end.send(("stop", self._user_stopped))
            except (OSError, ValueError, BrokenPipeError):
                pass

    def _abort_launch(self):
        self.stop()
        for proc in list(self.processes.values()):
            proc.join(timeout=5)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=5)
        self._close_servers()

    # ------------------------------------------------------------- monitor
    def _may_restart(self, name: str) -> bool:
        """Whether worker ``name`` is still inside its restart budget (the
        exit-kind half of the decision waits for the exit code)."""
        return (self._policy is not None
                and not self._stop.is_set()
                and name in self._payloads
                and self._restarts.get(name, 0) < self._policy.max_restarts)

    def _drain_errors(self):
        import queue as queue_lib
        while True:
            try:
                name, exc = self._error_queue.get_nowait()
            except (queue_lib.Empty, OSError, EOFError):
                return
            if self._may_restart(name):
                # A restart-eligible worker's error is held back until its
                # death is classified: a restarted crash is logged, not
                # fatal.  If the supervisor declines the restart the error
                # surfaces through the normal fail-fast path below.
                self._stashed[name] = exc
            else:
                self._reported.add(name)
                self._record_error(exc)

    def _restart_metric(self):
        if self._m_restarts is None:
            if not _telemetry.enabled():
                return None
            self._m_restarts = _telemetry.counter("resilience/restarts")
        return self._m_restarts

    def _handle_death(self, name: str, proc) -> bool:
        """Classify a dead worker and either schedule its respawn (True:
        keep it pending) or surface the failure fail-fast (False)."""
        kind = classify_exit(proc.exitcode, stopping=self._stop.is_set())
        self._exit_kinds.setdefault(name, []).append(kind)
        count = self._restarts.get(name, 0)
        if (self._policy is not None and name in self._payloads
                and not self._stop.is_set()
                and self._policy.should_restart(kind, count)):
            delay = self._policy.backoff(count)
            self._restarts[name] = count + 1
            stashed = self._stashed.pop(name, None)
            detail = f": {type(stashed).__name__}: {stashed}" if stashed \
                else ""
            print(f"[launcher] worker {name!r} {kind} (exit "
                  f"{proc.exitcode}){detail} — restart "
                  f"{count + 1}/{self._policy.max_restarts} in "
                  f"{delay:.2f}s", file=sys.stderr, flush=True)
            metric = self._restart_metric()
            if metric:
                metric.inc()
                _telemetry.counter(f"resilience/restarts/{name}").inc()
            self._respawn_at[name] = time.time() + delay
            return True
        stashed = self._stashed.pop(name, None)
        suppress = self._stop.is_set() and self._user_stopped
        if stashed is not None:
            self._reported.add(name)
            if not suppress:
                self._record_error(stashed)
        elif (proc.exitcode not in (0, None)
                and name not in self._reported and not suppress):
            self._record_error(RuntimeError(
                f"worker {name!r} died with exit code "
                f"{proc.exitcode} ({kind}) without reporting an error"))
        return False

    def _monitor(self):
        """Watchdog: surface child errors (and silent deaths) the moment
        they happen — fail-fast by default, elastic respawn for workers
        covered by the program's ``RestartPolicy``."""
        pending = set(self.processes)
        while pending:
            self._drain_errors()
            now = time.time()
            for name, due in list(self._respawn_at.items()):
                if self._stop.is_set():
                    self._respawn_at.pop(name, None)
                    pending.discard(name)
                elif now >= due:
                    self._respawn_at.pop(name, None)
                    self._reported.discard(name)
                    self._spawn(name, restarts=self._restarts[name])
            for name in list(pending):
                if name in self._respawn_at:
                    continue
                proc = self.processes[name]
                if proc.is_alive():
                    continue
                proc.join()
                # give the queue feeder a beat to deliver the child's own
                # error report before synthesizing one from the exit code
                d = time.time() + 1.0
                while (proc.exitcode not in (0, None)
                       and name not in self._reported
                       and name not in self._stashed
                       and time.time() < d):
                    self._drain_errors()
                    time.sleep(0.02)
                if not self._handle_death(name, proc):
                    pending.discard(name)
            time.sleep(0.05)
        self._drain_errors()

    # ---------------------------------------------------------------- stop
    def _initiate_stop(self):
        # the watchdog must not restore services into a run that is tearing
        # down (request only — joining here could self-deadlock when the
        # stop originates from the watchdog's own error path)
        if self._watchdog is not None:
            self._watchdog.request_stop()
        # order matters: children must see the stop (and its user/fail-fast
        # flavor) before any parent-side table wakes them with a "stopped"
        # rate-limiter error.  (list(): the monitor thread may be swapping
        # pipes for a respawn concurrently.)
        for pipe in list(self._control_pipes.values()):
            try:
                pipe.send(("stop", self._user_stopped))
            except (OSError, ValueError, BrokenPipeError):
                pass    # child already gone
        super()._initiate_stop()

    # ---------------------------------------------------------------- join
    def _join_runners(self, deadline: Optional[float]):
        super()._join_runners(deadline)
        if self._watchdog is not None:
            self._watchdog.join(timeout=5)
        for proc in list(self.processes.values()):
            remaining = (None if deadline is None
                         else max(deadline - time.time(), 0))
            proc.join(remaining)
        if self._monitor_thread is not None:
            alive = any(p.is_alive() for p in list(self.processes.values()))
            if not alive:
                self._monitor_thread.join(timeout=5)
        self._drain_errors()

    def _alive_nodes(self) -> List[str]:
        alive = super()._alive_nodes()
        alive.extend(name for name, p in list(self.processes.items())
                     if p.is_alive())
        return alive

    def _reap_stragglers(self, names: List[str]):
        for name in names:
            proc = self.processes.get(name)
            if proc is not None and proc.is_alive():
                # our own SIGTERM is not a worker death the monitor should
                # re-report
                self._reported.add(name)
                proc.terminate()
                proc.join(timeout=5)


_LAUNCHERS: Dict[str, Type[Launcher]] = {}


def register_launcher(name: str, cls: Type[Launcher]):
    """Register a backend under ``name`` for ``get_launcher`` lookup."""
    if not issubclass(cls, Launcher):
        raise TypeError(f"{cls!r} does not implement the Launcher protocol")
    _LAUNCHERS[name] = cls


def get_launcher(name: str) -> Type[Launcher]:
    """Resolve a backend name (``"local"``, ``"multiprocess"``, or any
    registered extension) to its Launcher class."""
    try:
        return _LAUNCHERS[name]
    except KeyError:
        raise ValueError(
            f"unknown launcher backend {name!r}; registered: "
            f"{sorted(_LAUNCHERS)}") from None


register_launcher("local", LocalLauncher)
register_launcher("multiprocess", MultiprocessLauncher)
