"""Launchpad-lite (§2.4): a distributed program is a graph of nodes.

Nodes are constructed lazily from factories; edges are *handles* — from the
node's perspective a handle is indistinguishable from the object itself
(Launchpad's key property: local vs remote calls look identical).  Execution
is pluggable (``repro.distributed.launchers``): the same graph runs on
threads (``local``) or on OS processes with courier RPC edges
(``multiprocess``), with no change to node code.

Node metadata (``Program.add_node``):

- ``role``: ``"worker"`` (a run loop the launcher schedules — actors,
  evaluators) or ``"service"`` (stateful, parent-resident, addressable by
  other nodes — replay shards, counters, variable sources).  A service whose
  instance defines ``run()`` additionally gets a parent-side thread (the
  learner is such a hybrid: it steps SGD *and* serves ``get_variables``).
- ``num_replicas``: expands the node into ``name/0 .. name/N-1`` replicas
  (actor pools, evaluator fleets); per-replica arguments are declared with
  the ``Replica`` wrapper and resolved at expansion time.
- ``interface``: the declared RPC surface — an allowlist of method names
  enforced both by the in-memory ``Handle`` and by the courier
  ``RemoteHandle``/``Server``, so moving a node across a process boundary
  never widens what its clients may call.

Handle pickling degrades gracefully: once a launcher has bound a courier
server to a node (``Program.bind_courier``), pickling any ``Handle`` to that
node yields a ``RemoteHandle`` RPC stub with identical call syntax; pickling
an unbound handle is a loud error rather than a silently broken proxy.
"""
from __future__ import annotations

import pickle
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

ROLES = ("worker", "service")


class Replica:
    """Per-replica argument: ``Replica(fn)`` is replaced by ``fn(i)`` for
    replica ``i`` when a replicated node is expanded (e.g. per-replica RNG
    seeds).  Resolution happens in the parent at ``add_node`` time, so the
    wrapped callable never needs to cross a process boundary."""

    def __init__(self, fn: Callable[[int], Any]):
        self.fn = fn

    def resolve(self, index: int) -> Any:
        return self.fn(index)


class Handle:
    """Lazy in-memory proxy to a node's constructed object (client side of an
    edge).  Pickling converts it to a courier ``RemoteHandle`` when the node
    has a bound courier server (see module docstring)."""

    def __init__(self, program: "Program", name: str):
        self._program = program
        self._name = name

    @property
    def node_name(self) -> str:
        return self._name

    def dereference(self):
        return self._program.resolve(self._name)

    def __getattr__(self, item):
        # method-call forwarding: handle.method(...) == object.method(...)
        # Dunder probes (copy.deepcopy, inspect) must NOT construct the node
        # as a side effect — report them absent instead.
        if item.startswith("__") and item.endswith("__"):
            raise AttributeError(item)
        node = self._program.node(self._name)
        if node.interface is not None and item not in node.interface:
            raise AttributeError(
                f"{item!r} is not in node {self._name!r}'s declared "
                f"interface {node.interface}")
        obj = self.dereference()
        return getattr(obj, item)

    def __reduce__(self):
        # Crossing a process boundary: degrade to an RPC stub bound to the
        # node's courier server, keeping call syntax identical.
        node = self._program.node(self._name)
        if node.courier_address is None:
            raise pickle.PicklingError(
                f"Handle to node {self._name!r} cannot cross a process "
                f"boundary: no courier server is bound to it (launchers "
                f"bind service nodes automatically; see Launcher.serve).")
        from repro.distributed.courier import RemoteHandle
        return (RemoteHandle,
                (node.courier_address, self._name, node.interface,
                 node.courier_authkey))


class Node:
    def __init__(self, name: str, factory: Callable[..., Any],
                 args: tuple, kwargs: dict, role: str,
                 interface: Optional[Tuple[str, ...]] = None,
                 replica_index: Optional[int] = None,
                 group: Optional[str] = None):
        self.name = name
        self.factory = factory
        self.args = args
        self.kwargs = kwargs
        self.role = role
        self.interface = interface
        self.replica_index = replica_index
        self.group = group or name
        self.instance: Any = None
        # Where a launcher placed this node: "inline" (not launched yet or
        # constructed-only), "thread" (parent thread), "process" (child OS
        # process — parent-side resolve is forbidden).
        self.placement = "inline"
        # (host, port) + authkey of the courier server wrapping this node,
        # if any.
        self.courier_address: Optional[Tuple[str, int]] = None
        self.courier_authkey: Optional[bytes] = None

    @property
    def is_worker(self) -> bool:
        return self.role == "worker"


class Program:
    def __init__(self, name: str = "program"):
        self.name = name
        self._nodes: Dict[str, Node] = {}
        self._order: List[str] = []
        # Set by the assembly layer (see repro.resilience.RestartPolicy):
        # launchers with elastic support respawn dead role="worker" nodes
        # under this policy instead of failing the whole run.
        self.restart_policy = None
        # Also set by assembly: the chaos policy (so the launcher-side
        # service watchdog can resolve kill schedules for role="service"
        # nodes — worker schedules resolve at assembly time instead) and
        # the cadence at which recoverable services are snapshotted for
        # failover.
        self.chaos_policy = None
        self.service_snapshot_period_s = 0.5
        # RLock: resolving a node dereferences its Handle arguments, which
        # re-enters resolve() on the same thread.
        self._lock = threading.RLock()

    def add_node(self, name: str, factory: Callable[..., Any], *args,
                 role: Optional[str] = None,
                 num_replicas: int = 1,
                 interface: Optional[Sequence[str]] = None,
                 is_worker: Optional[bool] = None,
                 **kwargs) -> Union[Handle, List[Handle]]:
        """Register a node (or ``num_replicas`` replicas of one).

        Returns a ``Handle`` — or a list of handles, one per replica, when
        ``num_replicas > 1`` (replicas are named ``name/0 .. name/N-1``).
        ``is_worker`` is the deprecated boolean spelling of
        ``role="worker"``.
        """
        if role is None:
            role = "worker" if is_worker else "service"
        elif is_worker is not None:
            raise ValueError("pass either role= or is_worker=, not both")
        if role not in ROLES:
            raise ValueError(f"role must be one of {ROLES}, got {role!r}")
        if num_replicas < 1:
            raise ValueError(f"num_replicas must be >= 1, got {num_replicas}")
        iface = tuple(interface) if interface is not None else None

        handles = []
        for i in range(num_replicas):
            args_i = tuple(a.resolve(i) if isinstance(a, Replica) else a
                           for a in args)
            kwargs_i = {k: (v.resolve(i) if isinstance(v, Replica) else v)
                        for k, v in kwargs.items()}
            if num_replicas == 1:
                self._register(Node(name, factory, args_i, kwargs_i, role,
                                    iface))
                return Handle(self, name)
            replica_name = f"{name}/{i}"
            self._register(Node(replica_name, factory, args_i, kwargs_i,
                                role, iface, replica_index=i, group=name))
            handles.append(Handle(self, replica_name))
        return handles

    def _register(self, node: Node):
        if node.name in self._nodes:
            raise ValueError(f"duplicate node {node.name!r}")
        self._nodes[node.name] = node
        self._order.append(node.name)

    def node(self, name: str) -> Node:
        return self._nodes[name]

    def resolve(self, name: str):
        with self._lock:
            node = self._nodes[name]
            if node.placement == "process":
                raise RuntimeError(
                    f"node {name!r} runs in a separate OS process; a "
                    f"parent-side resolve would construct a second instance. "
                    f"Talk to it through its handle / courier server.")
            if node.instance is None:
                args = [a.dereference() if isinstance(a, Handle) else a
                        for a in node.args]
                kwargs = {k: (v.dereference() if isinstance(v, Handle) else v)
                          for k, v in node.kwargs.items()}
                node.instance = node.factory(*args, **kwargs)
            return node.instance

    def bind_courier(self, name: str, address: Tuple[str, int],
                     authkey: Optional[bytes] = None):
        """Record the courier server (address + authkey) wrapping node
        ``name`` — from then on, pickling a Handle to it yields a
        ``RemoteHandle``."""
        self._nodes[name].courier_address = tuple(address)
        self._nodes[name].courier_authkey = authkey

    @property
    def nodes(self) -> List[Node]:
        return [self._nodes[n] for n in self._order]


def __getattr__(name):   # PEP 562 — keep old import sites working
    # LocalLauncher / WorkerErrors historically lived in this module; they
    # moved to repro.distributed.launchers with the pluggable-backend split.
    if name in ("LocalLauncher", "MultiprocessLauncher", "Launcher",
                "WorkerErrors", "JoinTimeout", "get_launcher",
                "register_launcher"):
        from repro.distributed import launchers
        return getattr(launchers, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
