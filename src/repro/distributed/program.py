"""Launchpad-lite (§2.4): a distributed program is a graph of nodes.

Nodes are constructed lazily from factories; edges are *handles* — from the
module's perspective a handle is indistinguishable from the object itself
(Launchpad's key property: local vs remote calls look identical).  The local
launcher runs each worker node in its own thread; a real fleet would place
each node in its own process/host with RPC edges, with no change to node code.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional


class Handle:
    """Lazy proxy to a node's constructed object (client side of an edge)."""

    def __init__(self, program: "Program", name: str):
        self._program = program
        self._name = name

    def dereference(self):
        return self._program.resolve(self._name)

    def __getattr__(self, item):
        # method-call forwarding: handle.method(...) == object.method(...)
        # Dunder probes (copy.deepcopy, pickle, inspect) must NOT construct
        # the node as a side effect — report them absent instead.
        if item.startswith("__") and item.endswith("__"):
            raise AttributeError(item)
        obj = self.dereference()
        return getattr(obj, item)


class WorkerErrors(RuntimeError):
    """Aggregate of every worker failure in a launched program (3.10-era
    stand-in for ExceptionGroup) — no error is silently dropped."""

    def __init__(self, errors: List[BaseException]):
        self.errors = list(errors)
        summary = "; ".join(f"[{i}] {type(e).__name__}: {e}"
                            for i, e in enumerate(self.errors))
        super().__init__(
            f"{len(self.errors)} worker(s) failed: {summary}")


class Node:
    def __init__(self, name: str, factory: Callable[..., Any],
                 args: tuple, kwargs: dict, is_worker: bool):
        self.name = name
        self.factory = factory
        self.args = args
        self.kwargs = kwargs
        self.is_worker = is_worker
        self.instance: Any = None


class Program:
    def __init__(self, name: str = "program"):
        self.name = name
        self._nodes: Dict[str, Node] = {}
        self._order: List[str] = []
        # RLock: resolving a node dereferences its Handle arguments, which
        # re-enters resolve() on the same thread.
        self._lock = threading.RLock()

    def add_node(self, name: str, factory: Callable[..., Any], *args,
                 is_worker: bool = False, **kwargs) -> Handle:
        if name in self._nodes:
            raise ValueError(f"duplicate node {name!r}")
        self._nodes[name] = Node(name, factory, args, kwargs, is_worker)
        self._order.append(name)
        return Handle(self, name)

    def resolve(self, name: str):
        with self._lock:
            node = self._nodes[name]
            if node.instance is None:
                args = [a.dereference() if isinstance(a, Handle) else a
                        for a in node.args]
                kwargs = {k: (v.dereference() if isinstance(v, Handle) else v)
                          for k, v in node.kwargs.items()}
                node.instance = node.factory(*args, **kwargs)
            return node.instance

    @property
    def nodes(self) -> List[Node]:
        return [self._nodes[n] for n in self._order]


class LocalLauncher:
    """Run worker nodes on threads (the single-machine Launchpad backend).

    Fail-fast: the first worker exception stops every sibling node instead of
    letting them spin until an external timeout.  Errors raised *after* the
    user requested shutdown — and rate-limiter wakeups caused by stopping the
    replay tables — are shutdown noise, not failures, and are suppressed.
    """

    def __init__(self, program: Program):
        self.program = program
        self.threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self._user_stopped = False
        self._errors: List[BaseException] = []
        self._errors_lock = threading.Lock()

    def launch(self):
        # construct everything first (resolves the graph edges)
        for node in self.program.nodes:
            self.program.resolve(node.name)
        for node in self.program.nodes:
            if not node.is_worker:
                continue
            t = threading.Thread(target=self._run_node, args=(node,),
                                 name=node.name, daemon=True)
            self.threads.append(t)
            t.start()
        return self

    def _run_node(self, node: Node):
        try:
            node.instance.run()
        except StopIteration:
            pass
        except Exception as e:
            from repro.replay.rate_limiter import RateLimiterTimeout
            # Once a stop is in flight (user- or fail-fast-initiated — the
            # flag is always set before any table is stopped), rate-limiter
            # wakeups are shutdown noise, as is anything raised after the
            # user asked us to shut down.  A "stopped" error with no stop in
            # flight is a real worker death and must be surfaced.
            if self._stop.is_set() and (self._user_stopped
                                        or isinstance(e, RateLimiterTimeout)):
                return
            with self._errors_lock:
                self._errors.append(e)
            # fail fast: stop the siblings so join() returns promptly
            self._initiate_stop()

    def should_stop(self) -> bool:
        return self._stop.is_set()

    def _initiate_stop(self):
        self._stop.set()
        for node in self.program.nodes:
            inst = node.instance
            if inst is not None and hasattr(inst, "stop"):
                try:
                    inst.stop()
                except Exception:
                    pass

    def stop(self):
        self._user_stopped = True
        self._initiate_stop()

    def join(self, timeout: Optional[float] = None):
        deadline = None if timeout is None else time.time() + timeout
        for t in self.threads:
            remaining = None if deadline is None else max(deadline - time.time(), 0)
            t.join(remaining)
        with self._errors_lock:
            errors = list(self._errors)
        if len(errors) == 1:
            raise errors[0]
        if errors:
            raise WorkerErrors(errors)
