"""Courier: a minimal socket RPC layer for program edges (§2.4).

When a ``Program`` node crosses a process boundary its in-memory ``Handle``
degrades to a ``RemoteHandle`` — same call syntax, but each method call is
forwarded to a ``Server`` wrapping the real object in the parent process.

Wire format (length-prefixed pickled frames, one request per response):

    frame    := uint32 big-endian payload length | pickled payload
    request  := (method_name: str, args: tuple, kwargs: dict)
    response := ("ok", result) | ("error", exception)

Errors re-raise in the caller with their original type when the exception
pickles (so e.g. a ``RateLimiterTimeout`` raised inside a remote replay
table is classified identically by local and remote callers); otherwise the
caller gets a ``RemoteError`` carrying the formatted remote traceback.

Servers enforce the node's declared ``interface`` (a method allowlist):
moving a service out-of-process never widens what its clients may call.
Connections are authenticated with an HMAC challenge (the unpickling server
must not accept frames from arbitrary local processes — CWE-502): each
``Server`` owns a random authkey, every accepted connection must answer
``HMAC(authkey, nonce)`` before its first frame is read, and the key
travels to legitimate clients only inside ``RemoteHandle`` pickles (process
spawn payloads / control pipes), never over the socket.
"""
from __future__ import annotations

import dataclasses
import hmac
import pickle
import secrets
import socket
import struct
import threading
import time
import traceback
from typing import Any, Optional, Sequence, Tuple

from repro.distributed.backoff import BackoffPolicy
from repro.telemetry import registry as _telemetry

_LEN = struct.Struct(">I")
_HOST = "127.0.0.1"
_NONCE_BYTES = 16
_DIGEST = "sha256"
_DIGEST_BYTES = 32
_AUTH_OK = b"OK"

# Methods safe to re-execute if the RESPONSE is lost: pure reads and
# latest-wins writes.  For these (and only these) a half-open connection is
# timed out and the call retried on a fresh socket; everything else keeps
# the strict no-retry-after-send rule below, because a lost response may
# mean the server already ran the (non-idempotent) method.
IDEMPOTENT_METHODS = frozenset({
    "get_variables", "get_counts", "size", "stats", "select_action",
    "push", "snapshot", "nodes", "num_pushes", "items",
})
# Recv timeout applied per attempt to idempotent calls: bounds how long a
# half-open connection (peer died without FIN) can stall a retryable read.
IDEMPOTENT_RECV_TIMEOUT_S = 30.0


@dataclasses.dataclass(frozen=True)
class RetryConfig:
    """Client-side retry behaviour for ``RemoteHandle`` calls.

    Two independent knobs, one shared ``BackoffPolicy``:

    - ``reconnect_deadline_s`` bounds the RECONNECT path: connection
      refused/reset before the request was delivered (including a service's
      restart window, and chaos-injected drops).  These are always safe to
      retry for any method — no bytes reached the server — so the client
      keeps retrying with jittered backoff until the deadline, then raises
      ``ServiceUnavailable``.
    - ``max_attempts`` bounds the RESPONSE-LOST path: the request was sent
      but the reply never arrived.  Only ``IDEMPOTENT_METHODS`` retry here
      (the server may already have executed a non-idempotent call).

    Process-global, installed via ``set_retry_config`` — plumbed from
    ``ExperimentConfig.rpc_retry`` into every worker.
    """

    max_attempts: int = 3
    reconnect_deadline_s: float = 5.0
    backoff: BackoffPolicy = BackoffPolicy()

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.reconnect_deadline_s <= 0:
            raise ValueError(f"reconnect_deadline_s must be > 0, "
                             f"got {self.reconnect_deadline_s}")
        if not isinstance(self.backoff, BackoffPolicy):
            raise TypeError(f"backoff must be a BackoffPolicy, "
                            f"got {type(self.backoff).__name__}")


DEFAULT_RETRY = RetryConfig()
_RETRY = DEFAULT_RETRY


def set_retry_config(config: Optional[RetryConfig]):
    """Install a process-wide retry config (None restores the default)."""
    global _RETRY
    if config is not None and not isinstance(config, RetryConfig):
        raise TypeError(f"expected RetryConfig or None, "
                        f"got {type(config).__name__}")
    _RETRY = config if config is not None else DEFAULT_RETRY


def retry_config() -> RetryConfig:
    return _RETRY

# Chaos injection point (see repro.resilience.chaos): when set, consulted
# client-side before every send — may sleep (delay) or raise
# ConnectionError (drop).  Faults fire before any bytes hit the wire, so a
# dropped call is always safe to retry regardless of idempotence.
_RPC_CHAOS = None


def set_rpc_chaos(injector):
    """Install (or clear, with None) a process-wide RPC fault injector."""
    global _RPC_CHAOS
    _RPC_CHAOS = injector


class CourierClosed(ConnectionError):
    """The peer closed the connection (server stopped, or vice versa)."""


class ServiceUnavailable(ConnectionError):
    """The service stayed unreachable past the reconnect deadline (its
    restart window exceeded the budget, or it is down for good) — or, when
    raised server-side, the service is marked down awaiting failover.  A
    ``ConnectionError`` subclass so degradation paths catch transport and
    application unavailability uniformly."""


class AuthenticationError(ConnectionRefusedError):
    """The courier HMAC handshake failed (missing/wrong authkey).  Never
    retried: backoff cannot fix a key mismatch, and fast-failing keeps a
    misconfigured client from hammering the server."""


class RemoteError(RuntimeError):
    """A remote call failed and the original exception could not be pickled
    back; carries the remote type name and formatted traceback."""


def picklable_error(e: BaseException) -> BaseException:
    """Return ``e`` if it survives a pickle ROUND-TRIP (dumps alone is not
    enough: multi-arg ``__init__`` exceptions dump fine but explode on
    loads), else a ``RemoteError`` carrying the formatted traceback.  Shared
    by the courier server and the launcher child error queue so both ship
    identically-shaped errors."""
    try:
        pickle.loads(pickle.dumps(e))
        return e
    except Exception:
        return RemoteError(f"{type(e).__name__}: {e}\n"
                           f"--- remote traceback ---\n"
                           f"{traceback.format_exc()}")


def _send_frame(sock: socket.socket, obj: Any) -> int:
    """Send one frame; returns the payload size in bytes (for telemetry)."""
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_LEN.pack(len(payload)) + payload)
    return len(payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except OSError as e:
            e.bytes_read = len(buf)
            raise
        if not chunk:
            err = CourierClosed("connection closed mid-frame"
                                if buf else "connection closed")
            err.bytes_read = len(buf)
            raise err
        buf += chunk
    return buf


def _recv_frame(sock: socket.socket) -> Tuple[Any, int]:
    """Receive one frame; returns ``(obj, payload_bytes)``.

    A connection failure (clean EOF or reset — the FIN/RST race makes
    either equally likely when the peer died) before the first byte of
    the length prefix is tagged ``no_response=True`` on the raised
    exception: the peer never wrote a single response byte, which the
    client's retry logic distinguishes from a mid-response failure.
    Timeouts are never tagged (the peer may be alive but slow).
    """
    try:
        (length,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    except (CourierClosed, OSError) as e:
        if getattr(e, "bytes_read", None) == 0 \
                and not isinstance(e, (socket.timeout, TimeoutError)):
            e.no_response = True
        raise
    return pickle.loads(_recv_exact(sock, length)), length


def _rpc_metrics(cache: dict, side: str, name: str, method: str):
    """Lazy per-method RPC metrics: ``(latency_ms hist, bytes_sent counter,
    bytes_recv counter)``, or None while telemetry is disabled.

    Checked at CALL time, not construction time, because handles unpickle
    in spawn children *before* ``WorkerTelemetry.install()`` configures the
    child's registry.  Cached per method after the first enabled call; the
    benign dict race under concurrent serve threads at worst recreates the
    same tuple.
    """
    metrics = cache.get(method)
    if metrics is None:
        if not _telemetry.enabled():
            return None
        base = f"courier/{side}/{name or 'anon'}/{method}"
        metrics = (_telemetry.histogram(f"{base}/latency_ms"),
                   _telemetry.counter(f"{base}/bytes_sent"),
                   _telemetry.counter(f"{base}/bytes_recv"))
        cache[method] = metrics
    return metrics


class Server:
    """Serve method calls on ``target`` over a localhost socket.

    One lightweight thread per client connection (clients hold persistent
    connections); ``interface`` restricts which methods may be invoked.
    """

    def __init__(self, target: Any, interface: Optional[Sequence[str]] = None,
                 name: str = "courier", host: str = _HOST, port: int = 0,
                 authkey: Optional[bytes] = None):
        self.target = target
        self.name = name
        self.interface = tuple(interface) if interface is not None else None
        self.authkey = authkey if authkey is not None \
            else secrets.token_bytes(32)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen()
        self.address: Tuple[str, int] = self._sock.getsockname()
        self._stopped = threading.Event()
        self._accept_thread: Optional[threading.Thread] = None
        self._conns = set()
        self._conns_lock = threading.Lock()
        self._rpc_metrics: dict = {}

    def start(self) -> "Server":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"courier/{self.name}",
            daemon=True)
        self._accept_thread.start()
        return self

    def _accept_loop(self):
        while not self._stopped.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:   # listening socket closed by stop()
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conns_lock:
                self._conns.add(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name=f"courier/{self.name}/conn",
                             daemon=True).start()

    def _authenticate(self, conn: socket.socket) -> bool:
        """Challenge-response before any frame is unpickled: send a nonce,
        require HMAC(authkey, nonce) back."""
        try:
            nonce = secrets.token_bytes(_NONCE_BYTES)
            conn.sendall(nonce)
            digest = _recv_exact(conn, _DIGEST_BYTES)
            expected = hmac.new(self.authkey, nonce, _DIGEST).digest()
            if not hmac.compare_digest(digest, expected):
                return False
            conn.sendall(_AUTH_OK)
            return True
        except (CourierClosed, OSError):
            return False

    def _serve_conn(self, conn: socket.socket):
        try:
            if not self._authenticate(conn):
                return
            while not self._stopped.is_set():
                try:
                    (method, args, kwargs), bytes_in = _recv_frame(conn)
                except (CourierClosed, OSError, EOFError):
                    return
                metrics = _rpc_metrics(self._rpc_metrics, "server",
                                       self.name, method)
                t0 = time.monotonic() if metrics else 0.0
                response = self._dispatch(method, args, kwargs)
                try:
                    bytes_out = _send_frame(conn, response)
                except OSError:
                    return
                except Exception as e:
                    # the RESULT failed to pickle (dumps happens before any
                    # bytes hit the wire): answer with an error frame
                    # instead of silently killing the connection.
                    bytes_out = _send_frame(conn, ("error", RemoteError(
                        f"response of {self.name!r}.{method} could not be "
                        f"pickled: {type(e).__name__}: {e}")))
                if metrics:
                    latency, sent, received = metrics
                    latency.observe((time.monotonic() - t0) * 1000.0)
                    sent.inc(bytes_out)
                    received.inc(bytes_in)
        except OSError:
            return
        finally:
            with self._conns_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _dispatch(self, method: str, args: tuple, kwargs: dict):
        try:
            if self.interface is not None and method not in self.interface:
                raise AttributeError(
                    f"{method!r} is not in service {self.name!r}'s declared "
                    f"interface {self.interface}")
            result = getattr(self.target, method)(*args, **kwargs)
            return ("ok", result)
        except BaseException as e:   # noqa: BLE001 — forwarded to the caller
            return ("error", picklable_error(e))

    def stop(self):
        self._stopped.set()
        # shutdown() BEFORE close(): the accept thread is blocked inside
        # the accept(2) syscall, which on Linux keeps the open file
        # description referenced — a bare close() would leave the socket
        # LISTENING (and the port unbindable for a failover re-bind at the
        # same address) until that thread wakes, which it never would.
        # shutdown() interrupts the blocked accept immediately.
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            # same reasoning: serve threads are blocked in recv(2)
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass

    close = stop


class RemoteHandle:
    """Pickle-able RPC stub: ``handle.method(...)`` forwards over courier.

    Drop-in for the in-memory ``Handle`` — node code cannot tell which one
    it holds (the Launchpad transparency property, now across processes).
    The socket is opened lazily and never pickled; unpickling in another
    process yields a fresh stub bound to the same server address.
    """

    def __init__(self, address: Tuple[str, int], name: str = "",
                 interface: Optional[Sequence[str]] = None,
                 authkey: Optional[bytes] = None):
        self._address = tuple(address)
        self._name = name
        self._interface = tuple(interface) if interface is not None else None
        self._authkey = authkey
        self._sock: Optional[socket.socket] = None
        self._lock = threading.Lock()
        self._rpc_metrics: dict = {}
        self._m_retries = None
        self._m_reconnects = None

    def _retries_metric(self):
        # Lazy like _rpc_metrics: handles unpickle before the child's
        # telemetry registry is configured.
        if self._m_retries is None:
            if not _telemetry.enabled():
                return None
            self._m_retries = _telemetry.counter(
                f"courier/client/{self._name or 'anon'}/retries")
        return self._m_retries

    def _reconnects_metric(self):
        if self._m_reconnects is None:
            if not _telemetry.enabled():
                return None
            self._m_reconnects = (
                _telemetry.counter("courier/reconnects"),
                _telemetry.counter(
                    f"courier/client/{self._name or 'anon'}/reconnects"))
        return self._m_reconnects

    @property
    def address(self) -> Tuple[str, int]:
        return self._address

    @property
    def node_name(self) -> str:
        return self._name

    def _connect(self) -> socket.socket:
        sock = socket.create_connection(self._address, timeout=30)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(None)
        try:
            nonce = _recv_exact(sock, _NONCE_BYTES)
            key = self._authkey if self._authkey is not None else b""
            sock.sendall(hmac.new(key, nonce, _DIGEST).digest())
            if _recv_exact(sock, len(_AUTH_OK)) != _AUTH_OK:
                raise CourierClosed("bad auth ack")
        except (CourierClosed, OSError) as e:
            try:
                sock.close()
            except OSError:
                pass
            raise AuthenticationError(
                f"courier authentication with {self._name!r} @ "
                f"{self._address} failed (missing/wrong authkey)") from e
        return sock

    def _drop_socket(self):
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _reconnect_backoff(self, cause: BaseException, reconnects: int,
                           deadline: Optional[float],
                           cfg: RetryConfig) -> float:
        """Sleep before the next reconnect attempt, or raise
        ``ServiceUnavailable`` once the per-call deadline has passed.
        Returns the deadline (set lazily at the first failure, so healthy
        calls never pay a clock read)."""
        now = time.monotonic()
        if deadline is None:
            deadline = now + cfg.reconnect_deadline_s
        if now >= deadline:
            raise ServiceUnavailable(
                f"service {self._name!r} @ {self._address} unreachable for "
                f"{cfg.reconnect_deadline_s:.1f}s "
                f"({reconnects} reconnect attempts): "
                f"{type(cause).__name__}: {cause}") from cause
        time.sleep(min(cfg.backoff.delay(reconnects),
                       max(deadline - now, 0.0)))
        return deadline

    def call(self, method: str, *args, **kwargs):
        metrics = _rpc_metrics(self._rpc_metrics, "client",
                               self._name, method)
        t0 = time.monotonic() if metrics else 0.0
        idempotent = method in IDEMPOTENT_METHODS
        cfg = _RETRY
        retries = 0      # response-lost retries (idempotent methods only)
        reconnects = 0   # pre-delivery failures retried under the deadline
        deadline = None
        with self._lock:
            # Failures BEFORE the request was delivered — connect refused/
            # reset (a service's restart window), send failure (sendall
            # raised, so the full frame never left this process), or an
            # injected chaos drop — are safe to retry for ANY method: the
            # server cannot have executed the call.  These reconnect with
            # jittered backoff until ``reconnect_deadline_s``, then raise
            # ``ServiceUnavailable``.  Auth failures fast-fail (a wrong key
            # is not transient).  After a send went through there is NO
            # retry for general methods: the server may already have run
            # the (non-idempotent) call, so a lost response must surface as
            # an error rather than silently run the method twice.
            # IDEMPOTENT_METHODS relax this: their recv is bounded by a
            # timeout (half-open peers) and retried on a fresh connection,
            # up to ``max_attempts``.
            while True:
                try:
                    if _RPC_CHAOS is not None:
                        _RPC_CHAOS.before_send()
                except ConnectionError as e:
                    self._drop_socket()
                    deadline = self._reconnect_backoff(
                        e, reconnects, deadline, cfg)
                    reconnects += 1
                    continue
                try:
                    if self._sock is None:
                        self._sock = self._connect()
                except AuthenticationError:
                    raise
                except (ConnectionError, OSError) as e:
                    deadline = self._reconnect_backoff(
                        e, reconnects, deadline, cfg)
                    reconnects += 1
                    continue
                try:
                    bytes_out = _send_frame(self._sock,
                                            (method, args, kwargs))
                except (ConnectionError, OSError) as e:
                    self._drop_socket()
                    deadline = self._reconnect_backoff(
                        e, reconnects, deadline, cfg)
                    reconnects += 1
                    continue
                if idempotent:
                    self._sock.settimeout(IDEMPOTENT_RECV_TIMEOUT_S)
                try:
                    (status, payload), bytes_in = _recv_frame(self._sock)
                except (CourierClosed, ConnectionError, OSError) as e:
                    self._drop_socket()
                    if getattr(e, "no_response", False):
                        # Keep-alive race: the connection died (clean EOF
                        # or reset) before a single response byte.  Either
                        # the frame only made it into the local TCP buffer
                        # of a connection whose peer was already gone, or a
                        # dying server accepted + authed and then shut the
                        # connection without dispatching — in both cases
                        # the handler never responded, so treat it like a
                        # pre-delivery failure and reconnect, for ANY
                        # method.  (The residual window — server executed
                        # the call, then died before writing byte one of
                        # the response — is exactly the state a failover
                        # restore rolls back to its last snapshot, so
                        # retrying is the correct semantics there too.)
                        # Mid-response failures and timeouts keep the
                        # strict rule below: the server saw the call and
                        # may have run it to completion.
                        deadline = self._reconnect_backoff(
                            e, reconnects, deadline, cfg)
                        reconnects += 1
                        continue
                    retries += 1
                    if not idempotent or retries >= cfg.max_attempts:
                        raise
                    time.sleep(cfg.backoff.delay(retries - 1))
                    continue
                if idempotent:
                    self._sock.settimeout(None)
                break
        if retries:
            m_retries = self._retries_metric()
            if m_retries:
                m_retries.inc(retries)
        if reconnects:
            m_reconnects = self._reconnects_metric()
            if m_reconnects:
                for m in m_reconnects:
                    m.inc(reconnects)
        if metrics:
            latency, sent, received = metrics
            latency.observe((time.monotonic() - t0) * 1000.0)
            sent.inc(bytes_out)
            received.inc(bytes_in)
        if status == "error":
            raise payload
        return payload

    def __getattr__(self, item):
        # underscore-prefixed names (which include all dunder probes) are
        # never forwarded as remote methods
        if item.startswith("_"):
            raise AttributeError(item)
        if self._interface is not None and item not in self._interface:
            raise AttributeError(
                f"{item!r} is not in node {self._name!r}'s declared "
                f"interface {self._interface}")
        return _RemoteMethod(self, item)

    def dereference(self):
        """Parity with Handle: a remote handle dereferences to itself (there
        is no local instance on this side of the boundary)."""
        return self

    def close(self):
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.close()
                except OSError:
                    pass
                self._sock = None

    def __reduce__(self):
        return (RemoteHandle,
                (self._address, self._name, self._interface, self._authkey))

    def __repr__(self):
        return (f"RemoteHandle({self._name!r} @ "
                f"{self._address[0]}:{self._address[1]})")


class _RemoteMethod:
    """Bound remote method (picklable, reusable)."""

    def __init__(self, handle: RemoteHandle, method: str):
        self._handle = handle
        self._method = method

    def __call__(self, *args, **kwargs):
        return self._handle.call(self._method, *args, **kwargs)

    def __reduce__(self):
        return (_RemoteMethod, (self._handle, self._method))


def serve(target: Any, interface: Optional[Sequence[str]] = None,
          name: str = "courier") -> Tuple[Server, RemoteHandle]:
    """Wrap ``target`` in a started courier server and return
    ``(server, handle)`` — the one-liner for exporting any object over RPC."""
    server = Server(target, interface=interface, name=name).start()
    return server, RemoteHandle(server.address, name=name,
                                interface=interface,
                                authkey=server.authkey)
