"""Shared jittered-exponential-backoff policy for retry loops.

One helper, two consumers (both in ``repro.distributed.courier``): the
idempotent-retry path (response lost after a request was sent) and the
reconnect path (connection refused/reset during a service's restart
window).  Delays grow geometrically from ``base_s`` up to ``max_s`` and
are jittered DOWNWARD — ``delay`` is drawn uniformly from
``[(1 - jitter) * full, full]`` — so a fleet of clients stampeding a
restarting service decorrelates without ever waiting longer than the
deterministic schedule.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Jittered exponential backoff: ``min(base * factor**attempt, max)``."""

    base_s: float = 0.05
    factor: float = 2.0
    max_s: float = 2.0
    jitter: float = 0.5  # fraction of the delay that may be shaved off

    def __post_init__(self):
        if self.base_s < 0:
            raise ValueError(f"base_s must be >= 0, got {self.base_s}")
        if self.factor < 1.0:
            raise ValueError(f"factor must be >= 1, got {self.factor}")
        if self.max_s < 0:
            raise ValueError(f"max_s must be >= 0, got {self.max_s}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")

    def delay(self, attempt: int, rng: Optional[random.Random] = None) -> float:
        """Delay in seconds before retry number ``attempt`` (0-indexed)."""
        full = min(self.base_s * self.factor ** max(attempt, 0), self.max_s)
        if not self.jitter or full <= 0:
            return full
        draw = (rng or random).random()
        return full * (1.0 - self.jitter * draw)
