"""Distributed execution (§2.4): Program graph + pluggable launchers.

``program`` declares the graph (nodes, roles, replicas, RPC interfaces),
``courier`` is the socket RPC layer its edges degrade to across process
boundaries (with ``RetryConfig``-governed reconnect/backoff and a typed
``ServiceUnavailable`` once a peer stays down past the deadline), and
``launchers`` holds the backend registry
(``get_launcher("local" | "multiprocess")``).
"""
from repro.distributed.backoff import BackoffPolicy  # noqa: F401
from repro.distributed.courier import (  # noqa: F401
    RemoteError, RemoteHandle, RetryConfig, Server, ServiceUnavailable,
    serve, set_retry_config)
from repro.distributed.launchers import (  # noqa: F401
    JoinTimeout, Launcher, LauncherBase, LocalLauncher, MultiprocessLauncher,
    WorkerErrors, get_launcher, register_launcher)
from repro.distributed.program import (  # noqa: F401
    Handle, Node, Program, Replica)
