"""Qwen1.5-MoE-A2.7B — 60 routed experts top-4 + 4 shared [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,                      # ffn is fully MoE
    vocab_size=151936,
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408, num_shared=4),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
