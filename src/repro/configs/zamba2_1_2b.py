"""Zamba2-1.2B — hybrid Mamba2 stack + shared attention block [arXiv:2411.15242]."""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,                # 32 heads * 64 = 2048 for the shared block
    d_ff=8192,                  # shared block MLP
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4),
    hybrid_attn_every=6,        # shared attn+mlp applied after every 6 mamba layers
    source="arXiv:2411.15242",
)
