"""Yi-6B — llama-arch GQA decoder [arXiv:2403.04652]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    source="arXiv:2403.04652",
)
