"""CodeQwen1.5-7B — dense MHA decoder [hf:Qwen/CodeQwen1.5-7B]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    source="hf:Qwen/CodeQwen1.5-7B",
)
