"""Mamba2-780m — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    arch_type="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
