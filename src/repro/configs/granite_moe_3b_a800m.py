"""Granite-MoE-3B-A800M — 40 routed experts top-8 [hf:ibm-granite/granite-3.0 family]."""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=0,
    vocab_size=49155,
    # group_size=64: with top-8 routing and tiny d_expert the dispatch einsum
    # costs g*k*cf*D MACs/token — 64-token groups keep it <15% of expert FLOPs
    # (see EXPERIMENTS.md §Perf, iterations G4-G6).
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512, num_shared=0,
                  group_size=64),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
