"""Architecture + input-shape registry.

``get_arch(name)`` resolves any of the 10 assigned architectures;
``reduced(cfg)`` produces the CPU-smoke variant (2 layers, d_model<=512,
<=4 experts) of the same family used by the per-arch smoke tests.
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, InputShape, MoEConfig, SSMConfig, INPUT_SHAPES

from repro.configs.codeqwen1_5_7b import CONFIG as CODEQWEN15_7B
from repro.configs.zamba2_1_2b import CONFIG as ZAMBA2_1_2B
from repro.configs.yi_6b import CONFIG as YI_6B
from repro.configs.qwen3_1_7b import CONFIG as QWEN3_1_7B
from repro.configs.qwen2_moe_a2_7b import CONFIG as QWEN2_MOE_A2_7B
from repro.configs.internvl2_26b import CONFIG as INTERNVL2_26B
from repro.configs.mamba2_780m import CONFIG as MAMBA2_780M
from repro.configs.whisper_base import CONFIG as WHISPER_BASE
from repro.configs.deepseek_7b import CONFIG as DEEPSEEK_7B
from repro.configs.granite_moe_3b_a800m import CONFIG as GRANITE_MOE_3B_A800M

ARCHS = {
    c.name: c
    for c in (
        CODEQWEN15_7B,
        ZAMBA2_1_2B,
        YI_6B,
        QWEN3_1_7B,
        QWEN2_MOE_A2_7B,
        INTERNVL2_26B,
        MAMBA2_780M,
        WHISPER_BASE,
        DEEPSEEK_7B,
        GRANITE_MOE_3B_A800M,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests."""
    d_model = min(cfg.d_model, 256)
    head_dim = 32 if cfg.head_dim else 0
    num_heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    num_kv = min(cfg.num_kv_heads, max(1, num_heads // 2)) if cfg.num_kv_heads else 0
    # keep GQA shape legal
    if num_heads and num_kv:
        while num_heads % num_kv:
            num_kv -= 1
    updates = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        sliding_window=None,
    )
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_expert=64,
            num_shared=min(cfg.moe.num_shared, 1))
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk_size=32)
    if cfg.hybrid_attn_every:
        updates["hybrid_attn_every"] = 1
    if cfg.encoder_layers:
        updates["encoder_layers"] = 2
        updates["encoder_seq"] = 16
    if cfg.vision_tokens:
        updates["vision_tokens"] = 8
    return dataclasses.replace(cfg, **updates)


__all__ = [
    "ARCHS", "get_arch", "get_shape", "reduced",
    "ArchConfig", "InputShape", "MoEConfig", "SSMConfig", "INPUT_SHAPES",
]
