"""InternVL2-26B — InternViT (stubbed) + InternLM2 LM backbone [arXiv:2404.16821].

The vision encoder + MLP projector are a stub per the brief: ``input_specs``
supplies precomputed patch embeddings of shape (batch, vision_tokens, d_model)
which the decoder interleaves before the text tokens.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    arch_type="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    vision_tokens=256,           # one 448px tile -> 256 projected patch tokens
    source="arXiv:2404.16821",
)
