"""Whisper-base — encoder-decoder transformer backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a stub: ``input_specs`` provides
precomputed frame embeddings (batch, 1500, d_model) consumed by the encoder.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,                # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    encoder_layers=6,
    encoder_seq=1500,
    rope_theta=0.0,              # whisper uses learned/sinusoidal, we use sinusoid
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
