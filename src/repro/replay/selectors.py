"""Reverb sampling distributions: Fifo, Lifo, Uniform, Prioritized.

Prioritized uses a sum-tree for O(log n) sampling with p_i^alpha weighting
(Schaul et al., 2015) — the same scheme Acme's DQN/R2D2 use.
"""
from __future__ import annotations

import random
from typing import Dict, List, Tuple

import numpy as np


class Selector:
    consumes: bool = False     # True => sampling removes the item (queues)

    def insert(self, key: int, priority: float): ...
    def remove(self, key: int): ...
    def update(self, key: int, priority: float): ...
    def size(self) -> int:
        raise NotImplementedError

    def sample(self) -> Tuple[int, float]:
        """Returns (key, probability_of_selection)."""
        raise NotImplementedError

    # -- exact-resume serialization ------------------------------------
    # Selectors that implement both hooks restart bit-exactly: the same
    # sample() draws come out after a save/load round trip.  Selectors
    # that don't are rebuilt from the table's items on restore (correct
    # distribution, fresh RNG stream — not bit-exact).
    def state_dict(self) -> dict:
        raise NotImplementedError(
            f"{type(self).__name__} does not support exact-resume "
            "serialization; the table will rebuild it from item priorities")

    def load_state_dict(self, state: dict):
        raise NotImplementedError


class Fifo(Selector):
    consumes = True

    def __init__(self):
        self._keys: List[int] = []

    def size(self):
        return len(self._keys)

    def insert(self, key, priority):
        self._keys.append(key)

    def remove(self, key):
        try:
            self._keys.remove(key)
        except ValueError:
            pass

    def update(self, key, priority):
        pass

    def sample(self):
        if not self._keys:
            raise IndexError("empty")
        return self._keys.pop(0), 1.0

    def state_dict(self):
        return {"kind": type(self).__name__, "keys": list(self._keys)}

    def load_state_dict(self, state):
        self._keys = list(state["keys"])


class Lifo(Fifo):
    def sample(self):
        if not self._keys:
            raise IndexError("empty")
        return self._keys.pop(), 1.0


class Uniform(Selector):
    def __init__(self, seed: int = 0):
        self._keys: List[int] = []
        self._pos: Dict[int, int] = {}
        self._rng = random.Random(seed)

    def insert(self, key, priority):
        self._pos[key] = len(self._keys)
        self._keys.append(key)

    def remove(self, key):
        pos = self._pos.pop(key, None)
        if pos is None:
            return
        last = self._keys.pop()
        if last != key:
            self._keys[pos] = last
            self._pos[last] = pos

    def update(self, key, priority):
        pass

    def sample(self):
        if not self._keys:
            raise IndexError("empty")
        k = self._rng.choice(self._keys)
        return k, 1.0 / len(self._keys)

    def state_dict(self):
        # _keys order matters: rng.choice indexes into it, so restoring the
        # same order + the same rng state reproduces the draw sequence.
        return {"kind": "Uniform", "keys": list(self._keys),
                "rng": self._rng.getstate()}

    def load_state_dict(self, state):
        self._keys = list(state["keys"])
        self._pos = {k: i for i, k in enumerate(self._keys)}
        self._rng.setstate(state["rng"])


class SumTree:
    """Classic array-backed sum tree over slot indices."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.tree = np.zeros(2 * capacity, np.float64)

    def set(self, idx: int, value: float):
        i = idx + self.capacity
        delta = value - self.tree[i]
        while i:
            self.tree[i] += delta
            i //= 2

    def get(self, idx: int) -> float:
        return float(self.tree[idx + self.capacity])

    def total(self) -> float:
        return float(self.tree[1])

    def find(self, mass: float) -> int:
        i = 1
        while i < self.capacity:
            left = 2 * i
            if mass <= self.tree[left] or self.tree[left + 1] <= 0:
                i = left
            else:
                mass -= self.tree[left]
                i = left + 1
        return i - self.capacity


class Prioritized(Selector):
    def __init__(self, priority_exponent: float = 0.6, capacity: int = 1 << 20,
                 seed: int = 0):
        self.alpha = priority_exponent
        self._tree = SumTree(capacity)
        self._slot: Dict[int, int] = {}
        self._key_of: Dict[int, int] = {}
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self._rng = random.Random(seed)

    def _p(self, priority: float) -> float:
        return float(max(priority, 1e-12) ** self.alpha)

    def insert(self, key, priority):
        slot = self._free.pop()
        self._slot[key] = slot
        self._key_of[slot] = key
        self._tree.set(slot, self._p(priority))

    def remove(self, key):
        slot = self._slot.pop(key, None)
        if slot is None:
            return
        self._tree.set(slot, 0.0)
        self._key_of.pop(slot, None)
        self._free.append(slot)

    def update(self, key, priority):
        slot = self._slot.get(key)
        if slot is not None:
            self._tree.set(slot, self._p(priority))

    def sample(self):
        total = self._tree.total()
        if total <= 0:
            raise IndexError("empty")
        slot = self._tree.find(self._rng.random() * total)
        key = self._key_of.get(slot)
        if key is None:  # numerical edge: fall back to any live key
            key = next(iter(self._slot))
            slot = self._slot[key]
        return key, self._tree.get(slot) / total

    def state_dict(self):
        # The tree array is serialized VERBATIM: set() accumulates
        # incremental float deltas, so rebuilding from priorities would
        # round internal sums differently and shift find() boundaries —
        # breaking bit-exact resume.
        return {"kind": "Prioritized", "alpha": self.alpha,
                "capacity": self._tree.capacity,
                "tree": self._tree.tree.copy(),
                "slot": dict(self._slot),
                "free": list(self._free),
                "rng": self._rng.getstate()}

    def load_state_dict(self, state):
        if int(state["capacity"]) != self._tree.capacity:
            raise ValueError(
                f"Prioritized capacity mismatch: checkpoint has "
                f"{state['capacity']}, selector has {self._tree.capacity}")
        self.alpha = float(state["alpha"])
        self._tree.tree = np.asarray(state["tree"], np.float64).copy()
        self._slot = {int(k): int(s) for k, s in state["slot"].items()}
        self._key_of = {s: k for k, s in self._slot.items()}
        self._free = [int(s) for s in state["free"]]
        self._rng.setstate(state["rng"])
