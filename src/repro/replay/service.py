"""Sharded replay service (§2.4–§2.5 scaled out).

The paper's scaling story is that replay is a *service*: actors and learners
scale independently because they only ever talk to a rate-limited storage
layer.  A single ``Table`` serializes every insert, sample, and priority
update through one lock and one condition variable — the bottleneck every
distributed run funnels through.  ``ShardedReplay`` horizontally shards that
service: N full tables (each with its own selector and ``RateLimiter``),
constructed from the *same* ``builder.make_replay()`` factory so every
registered builder works unchanged.

Design:

- **Insert routing** — round-robin (default), a multiplicative hash of the
  insert ticket, or *affinity*: writers hold a ``ShardWriter`` view of one
  shard and insert shard-directly, bypassing the front-end's routing cursor
  entirely (the PR 4 follow-on — per-env adder streams land on assigned
  shards, so the actor→replay→learner pipeline is shard-parallel end to end
  with no cross-shard coordination).  All modes keep shards balanced so
  per-shard ``min_size_to_sample`` thresholds are reached together; under
  affinity the balance comes from the env→shard assignment being a
  round-robin of the fleet's global env ids.
- **Shard-id-encoded keys** — the global key of an item stored in shard ``i``
  with local key ``k`` is ``k * num_shards + i``; ``update_priorities`` can
  therefore route each key back to its owning shard without any lookup table.
- **Interleaved sampling** — a batch is drawn one item at a time from the
  shards in rotating round-robin order, i.e. the sampling distribution is a
  uniform mixture over shards; reported probabilities are scaled by
  ``1/num_shards`` accordingly.
- **Per-shard rate limiting** — each shard keeps its own ``RateLimiter``, so
  the §2.5 SPI invariant holds *per shard* (and thus in aggregate); the
  ``rate_limiter`` property is an aggregated read view whose ``inserts`` /
  ``samples`` / ``min_size_to_sample`` sum across shards.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.replay.table import Item, Table

# The declared RPC surface of a replay service node (shard or front-end):
# what a courier server wrapping it lets remote adders/learners call.  The
# distributed assembly layer attaches this to every replay node it emits, so
# each shard is independently courier-addressable (the seam a multi-host
# backend will use to place shards on remote replay servers).
REPLAY_INTERFACE = ("insert", "sample", "update_priorities", "size", "stats")

# Knuth's multiplicative hash constant: decorrelates consecutive tickets.
_HASH_MULT = 2654435761

# Insert-routing modes ShardedReplay accepts.  "affinity" means writers
# route themselves through ShardWriter views; the front-end falls back to
# round-robin for any insert that still reaches it directly.
ROUTING_MODES = ("round_robin", "hash", "affinity")


class ShardWriter:
    """Client-side single-shard view with global-key encoding.

    Wraps one shard (an in-memory ``Table`` or a courier handle to a
    ``replay/shard_i`` node — the call syntax is identical) and speaks the
    insert/priority surface adders and learners use, translating between
    the shard's LOCAL keys and the sharded service's GLOBAL keys
    (``global = local * num_shards + shard_idx``).  This is what gives
    per-env adders shard affinity: each env's adder writes straight to its
    assigned shard with zero front-end coordination, while the keys it
    observes stay interchangeable with the front-end's — priority updates
    route back to the owning shard through the same encoding.

    Picklable whenever the wrapped shard reference is (courier handles
    degrade to ``RemoteHandle`` stubs), so vectorized actor workers carry
    their writers across process boundaries.
    """

    def __init__(self, shard, shard_idx: int, num_shards: int):
        if not 0 <= shard_idx < num_shards:
            raise ValueError(
                f"shard_idx must be in [0, {num_shards}), got {shard_idx}")
        self.shard = shard
        self.shard_idx = shard_idx
        self.num_shards = num_shards
        self._m_inserts = None

    def insert(self, data, priority: float = 1.0,
               timeout: Optional[float] = None) -> int:
        local_key = self.shard.insert(data, priority, timeout=timeout)
        from repro.telemetry import registry as _telemetry
        if self._m_inserts is None and _telemetry.enabled():
            self._m_inserts = _telemetry.counter(
                f"replay/routing/shard_{self.shard_idx}/inserts")
        if self._m_inserts:
            self._m_inserts.inc()
        return local_key * self.num_shards + self.shard_idx

    def update_priorities(self, keys: Sequence[int],
                          priorities: Sequence[float]):
        """Global-key priority updates for items owned by THIS shard (keys
        owned by other shards are a routing bug, not a silent drop)."""
        locals_, ps = [], []
        for key, priority in zip(keys, priorities):
            local, idx = divmod(int(key), self.num_shards)
            if idx != self.shard_idx:
                raise ValueError(
                    f"key {key} belongs to shard {idx}, not this writer's "
                    f"shard {self.shard_idx}")
            locals_.append(local)
            ps.append(priority)
        if locals_:
            self.shard.update_priorities(locals_, ps)

    def size(self) -> int:
        return self.shard.size()

    def __getstate__(self):
        # the lazy metric is process-local (re-created where we land)
        return {"shard": self.shard, "shard_idx": self.shard_idx,
                "num_shards": self.num_shards}

    def __setstate__(self, state):
        self.shard = state["shard"]
        self.shard_idx = state["shard_idx"]
        self.num_shards = state["num_shards"]
        self._m_inserts = None


class _Ticket:
    """Monotonic routing cursor.  itertools.count would be marginally
    cheaper but can't be read or restored, and exact resume needs the
    insert/sample routing position to survive a checkpoint."""

    __slots__ = ("_lock", "_value")

    def __init__(self, start: int = 0):
        self._lock = threading.Lock()
        self._value = int(start)

    def next(self) -> int:
        with self._lock:
            value = self._value
            self._value += 1
            return value

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def set(self, value: int):
        with self._lock:
            self._value = int(value)


class AggregateRateLimiter:
    """Read-mostly view over the shards' limiters.

    Quacks like a ``RateLimiter`` for the stats and control surface the
    execution layers use (``inserts``/``samples``/``min_size_to_sample``/
    ``would_block_*``/``stop``); blocking itself stays per shard.
    """

    def __init__(self, shards: Sequence[Table]):
        self._shards = list(shards)

    @property
    def inserts(self) -> int:
        return sum(s.rate_limiter.inserts for s in self._shards)

    @property
    def samples(self) -> int:
        return sum(s.rate_limiter.samples for s in self._shards)

    @property
    def min_size_to_sample(self) -> int:
        return sum(s.rate_limiter.min_size_to_sample for s in self._shards)

    @property
    def stopped(self) -> bool:
        return any(s.rate_limiter.stopped for s in self._shards)

    def would_block_insert(self) -> bool:
        return any(s.rate_limiter.would_block_insert() for s in self._shards)

    def would_block_sample(self) -> bool:
        return any(s.rate_limiter.would_block_sample() for s in self._shards)

    def stop(self):
        for s in self._shards:
            s.rate_limiter.stop()


class ShardedReplay:
    """N replay shards behind the single-table interface.

    Drop-in for ``Table`` everywhere the execution layers touch replay:
    ``insert`` / ``sample`` / ``update_priorities`` / ``size`` / ``stop``,
    plus the ``selector`` / ``rate_limiter`` attributes that
    ``repro.agents.builders`` reads.  Construct via ``from_factory`` with the
    builder's own ``make_replay`` so sharding needs no per-agent code.
    """

    def __init__(self, shards: Sequence[Table], name: str = "sharded_replay",
                 routing: str = "round_robin"):
        if not shards:
            raise ValueError("ShardedReplay needs at least one shard")
        if routing not in ROUTING_MODES:
            raise ValueError(f"unknown routing {routing!r} "
                             f"(expected one of {ROUTING_MODES})")
        self.name = name
        self.shards: List[Table] = list(shards)
        self.num_shards = len(self.shards)
        self.routing = routing
        self.capacity = sum(s.capacity for s in self.shards)
        self.rate_limiter = AggregateRateLimiter(self.shards)
        self._insert_ticket = _Ticket()
        self._sample_ticket = _Ticket()

    @classmethod
    def from_factory(cls, make_replay: Callable[[], Table], num_shards: int,
                     routing: str = "round_robin") -> "ShardedReplay":
        """Build N shards from a builder's ``make_replay`` factory."""
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        shards = [make_replay() for _ in range(num_shards)]
        for i, shard in enumerate(shards):
            shard.name = f"{shard.name}/shard_{i}"
            # One factory means identical selector RNG streams; under the
            # lockstep sample rotation that would correlate cross-shard
            # draws (each shard picking the same position each round), so
            # give every shard a distinct deterministic stream.
            rng = getattr(shard.selector, "_rng", None)
            if rng is not None and i:
                rng.seed((i + 1) * _HASH_MULT)
        return cls(shards, name=f"sharded[{num_shards}]", routing=routing)

    # ------------------------------------------------------------ routing
    def _route(self) -> int:
        # "affinity" inserts normally arrive shard-directly via ShardWriter
        # views; anything still reaching the front-end (e.g. a restore
        # replaying transitions) falls back to the round-robin cursor.
        ticket = self._insert_ticket.next()
        if self.routing == "hash":
            return ((ticket * _HASH_MULT) >> 7) % self.num_shards
        return ticket % self.num_shards

    def shard_of(self, global_key: int) -> int:
        return global_key % self.num_shards

    def _global_key(self, local_key: int, shard_idx: int) -> int:
        return local_key * self.num_shards + shard_idx

    def shard_view(self, shard_idx: int) -> ShardWriter:
        """A ``ShardWriter`` over shard ``shard_idx``: shard-direct inserts
        that return GLOBAL keys (the in-memory counterpart of wiring a
        writer to a ``replay/shard_i`` courier handle)."""
        return ShardWriter(self.shards[shard_idx], shard_idx,
                           self.num_shards)

    # ------------------------------------------------------------ table api
    @property
    def selector(self):
        # Shards are homogeneous (one factory); expose shard 0's selector for
        # the ``consumes`` probe the synchronous agent loop performs.
        return self.shards[0].selector

    def insert(self, data, priority: float = 1.0,
               timeout: Optional[float] = None) -> int:
        idx = self._route()
        local_key = self.shards[idx].insert(data, priority, timeout=timeout)
        return self._global_key(local_key, idx)

    def sample(self, batch_size: int = 1,
               timeout: Optional[float] = None) -> List[Tuple[Item, float]]:
        """Interleaved cross-shard sampling: item j of the batch comes from
        shard (cursor + j) % N, each drawn under that shard's own limiter."""
        start = self._sample_ticket.next()
        out: List[Tuple[Item, float]] = []
        for j in range(batch_size):
            idx = (start + j) % self.num_shards
            (item, prob), = self.shards[idx].sample(1, timeout=timeout)
            out.append((Item(self._global_key(item.key, idx), item.data,
                             item.priority), prob / self.num_shards))
        return out

    def update_priorities(self, keys: Sequence[int],
                          priorities: Sequence[float]):
        by_shard: Dict[int, Tuple[List[int], List[float]]] = {}
        for key, priority in zip(keys, priorities):
            local, idx = divmod(int(key), self.num_shards)
            ks, ps = by_shard.setdefault(idx, ([], []))
            ks.append(local)
            ps.append(priority)
        for idx, (ks, ps) in by_shard.items():
            self.shards[idx].update_priorities(ks, ps)

    def size(self) -> int:
        return sum(s.size() for s in self.shards)

    @property
    def stopped(self) -> bool:
        return self.rate_limiter.stopped

    def stop(self):
        for s in self.shards:
            s.stop()

    # ----------------------------------------------------- exact resume
    def state_dict(self) -> Dict:
        """Per-shard table snapshots plus the routing cursors, so resumed
        inserts/samples land on the same shards they would have."""
        return {
            "num_shards": self.num_shards,
            "routing": self.routing,
            "shards": [s.state_dict() for s in self.shards],
            "insert_ticket": self._insert_ticket.value,
            "sample_ticket": self._sample_ticket.value,
        }

    def load_state_dict(self, state: Dict):
        if int(state["num_shards"]) != self.num_shards:
            raise ValueError(
                f"shard count mismatch: checkpoint has "
                f"{state['num_shards']}, service has {self.num_shards}")
        for shard, shard_state in zip(self.shards, state["shards"]):
            shard.load_state_dict(shard_state)
        self._insert_ticket.set(state["insert_ticket"])
        self._sample_ticket.set(state["sample_ticket"])

    # ------------------------------------------------------------ stats
    def stats(self) -> Dict:
        """Aggregated inserts/samples/size plus the per-shard breakdown the
        §2.5 invariant is checked against."""
        per_shard = []
        for s in self.shards:
            rl = s.rate_limiter
            per_shard.append({"name": s.name, "size": s.size(),
                              "inserts": rl.inserts, "samples": rl.samples,
                              "min_size_to_sample": rl.min_size_to_sample})
        return {"num_shards": self.num_shards,
                "size": self.size(),
                "inserts": self.rate_limiter.inserts,
                "samples": self.rate_limiter.samples,
                "per_shard": per_shard}


def make_replay_shards(make_replay: Callable[[], Table], num_shards: int,
                       routing: str = "round_robin"):
    """``num_shards <= 1`` keeps the plain single table (zero overhead);
    otherwise returns a ``ShardedReplay`` over N factory-built shards."""
    if num_shards <= 1:
        return make_replay()
    return ShardedReplay.from_factory(make_replay, num_shards,
                                      routing=routing)
