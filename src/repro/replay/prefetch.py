"""Prefetching learner pipeline (§2.3's dataset, off the hot path).

``as_iterator`` samples and stacks a batch *synchronously* inside the
learner's step — the learner pays replay latency (lock waits, rate-limiter
blocking, numpy stacking) on every batch.  ``PrefetchingDataset`` moves that
work onto background sampler threads feeding a bounded queue: the learner's
``next()`` is a queue pop, and sampling overlaps with gradient computation.

Two sources:

- ``PrefetchingDataset(table, batch_size, num_threads=k)`` — samples the
  table (or ``ShardedReplay``) directly from ``k`` threads; the fast path
  when the learner batch is plain ``as_iterator`` sampling.
- ``PrefetchingDataset.over_iterator(iterator)`` — wraps *any* batch
  iterator (e.g. DQfD/R2D3's demo-mixing dataset) with one background
  thread, preserving its exact sampling semantics.

The queue bound keeps the pipeline honest with respect to the §2.5 rate
limiter: at most ``prefetch_size`` batches are accounted to the limiter
ahead of what the learner has actually consumed.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

from repro.replay.dataset import ReplaySample, batch_from_samples
from repro.replay.rate_limiter import RateLimiterTimeout


class PrefetchingDataset:
    """Iterator of ``ReplaySample`` batches assembled by background threads.

    table: anything with ``sample(batch_size, timeout)`` and a ``stopped``
        property — a ``Table`` or a ``ShardedReplay``.
    batch_size: items per batch.
    prefetch_size: bounded queue depth (batches buffered ahead).
    num_threads: background sampler threads (>1 overlaps rate-limiter
        blocking and shard-lock waits across batches).
    """

    def __init__(self, table, batch_size: int, prefetch_size: int = 4,
                 num_threads: int = 1, poll_s: float = 0.2,
                 _iterator: Optional[Iterator[ReplaySample]] = None):
        if prefetch_size < 1:
            raise ValueError(
                f"prefetch_size must be >= 1, got {prefetch_size}")
        if num_threads < 1:
            raise ValueError(f"num_threads must be >= 1, got {num_threads}")
        if _iterator is None and batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self._table = table
        self._batch_size = batch_size
        self._iterator = _iterator
        self._poll_s = poll_s
        self._queue: "queue.Queue[ReplaySample]" = queue.Queue(prefetch_size)
        self._stop_event = threading.Event()
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"prefetch_{i}")
            for i in range(num_threads)]
        for t in self._threads:
            t.start()

    @classmethod
    def over_iterator(cls, iterator: Iterator[ReplaySample],
                      prefetch_size: int = 4,
                      poll_s: float = 0.2) -> "PrefetchingDataset":
        """Wrap an arbitrary batch iterator (single background thread — an
        iterator is not safe to advance concurrently)."""
        return cls(table=None, batch_size=0, prefetch_size=prefetch_size,
                   num_threads=1, poll_s=poll_s, _iterator=iterator)

    # ------------------------------------------------------------ workers
    def _produce(self) -> ReplaySample:
        if self._iterator is not None:
            return next(self._iterator)
        sampled = self._table.sample(self._batch_size, timeout=self._poll_s)
        return batch_from_samples(sampled)

    def _worker(self):
        while not self._stop_event.is_set():
            try:
                batch = self._produce()
            except StopIteration:
                self._stop_event.set()
                return
            except RateLimiterTimeout as e:
                if "stopped" in str(e) or getattr(self._table, "stopped",
                                                  False):
                    self._stop_event.set()
                continue
            while not self._stop_event.is_set():
                try:
                    self._queue.put(batch, timeout=self._poll_s)
                    break
                except queue.Full:
                    continue

    # ------------------------------------------------------------ iterator
    def __iter__(self):
        return self

    def __next__(self) -> ReplaySample:
        while True:
            try:
                return self._queue.get(timeout=self._poll_s)
            except queue.Empty:
                if self._stop_event.is_set():
                    raise RateLimiterTimeout("stopped")

    def qsize(self) -> int:
        return self._queue.qsize()

    def stop(self, timeout: Optional[float] = 2.0):
        self._stop_event.set()
        for t in self._threads:
            t.join(timeout)

    @property
    def closed(self) -> bool:
        return self._stop_event.is_set()

    def close(self, timeout: Optional[float] = 2.0):
        """Stop + drain: join the sampler threads and empty the queue so a
        stopped learner node releases its buffered batches — sequential
        runs in one process cannot accumulate leaked prefetch threads or
        buffered sample memory.  Idempotent; a consumer blocked in
        ``next()`` is woken with the "stopped" timeout."""
        self.stop(timeout)
        while True:
            try:
                self._queue.get_nowait()
            except queue.Empty:
                return
