from repro.replay.dataset import ReplaySample, SampleInfo, as_iterator, dataset_from_list  # noqa: F401
from repro.replay.rate_limiter import MinSize, RateLimiter, RateLimiterTimeout, SampleToInsertRatio  # noqa: F401
from repro.replay.selectors import Fifo, Lifo, Prioritized, Uniform  # noqa: F401
from repro.replay.table import Table  # noqa: F401
