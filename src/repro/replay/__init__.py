from repro.replay.dataset import (ReplaySample, SampleInfo, as_iterator,  # noqa: F401
                                  batch_from_samples, dataset_from_list)
from repro.replay.prefetch import PrefetchingDataset  # noqa: F401
from repro.replay.rate_limiter import MinSize, RateLimiter, RateLimiterTimeout, SampleToInsertRatio  # noqa: F401
from repro.replay.selectors import Fifo, Lifo, Prioritized, Uniform  # noqa: F401
from repro.replay.service import (REPLAY_INTERFACE, ROUTING_MODES,  # noqa: F401
                                  AggregateRateLimiter, ShardedReplay,
                                  ShardWriter, make_replay_shards)
from repro.replay.table import Table  # noqa: F401
