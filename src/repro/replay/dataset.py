"""Dataset iterators over replay tables (the learner-facing stream, §2.3).

``as_iterator`` yields batched pytrees (numpy, stacked along axis 0) exactly
like Acme's TF-Dataset-over-Reverb, including the sampled keys and
probabilities needed for prioritized replay importance weighting.
"""
from __future__ import annotations

from typing import Any, Iterator, NamedTuple, Optional

import jax
import numpy as np

from repro.replay.table import Table


class SampleInfo(NamedTuple):
    keys: np.ndarray
    probabilities: np.ndarray


class ReplaySample(NamedTuple):
    info: SampleInfo
    data: Any


def _stack(items):
    return jax.tree.map(lambda *xs: np.stack(xs, axis=0), *items)


def batch_from_samples(sampled) -> ReplaySample:
    """Assemble ``[(Item, prob), ...]`` into one stacked ReplaySample."""
    items = [it.data for it, _ in sampled]
    keys = np.array([it.key for it, _ in sampled], np.int64)
    probs = np.array([p for _, p in sampled], np.float64)
    return ReplaySample(SampleInfo(keys, probs), _stack(items))


class _TableIterator:
    """The infinite sample stream as a plain-class iterator, NOT a
    generator: an exception escaping a generator's frame (e.g. a transient
    ``ServiceUnavailable`` while the table's service restarts) finalizes
    the generator, and every later ``next()`` returns ``StopIteration`` —
    which learner run loops read as clean end-of-stream and exit on.  A
    class iterator has no frame to finalize: the exception propagates to
    the caller and the stream resumes on the next ``next()``."""

    __slots__ = ("_table", "_batch_size", "_timeout")

    def __init__(self, table, batch_size: int, timeout: Optional[float]):
        self._table = table
        self._batch_size = batch_size
        self._timeout = timeout

    def __iter__(self):
        return self

    def __next__(self) -> ReplaySample:
        return batch_from_samples(
            self._table.sample(self._batch_size, timeout=self._timeout))


def as_iterator(table: Table, batch_size: int,
                timeout: float = None) -> Iterator[ReplaySample]:
    return _TableIterator(table, batch_size, timeout)


def dataset_from_list(items, batch_size: int, *, seed: int = 0,
                      shuffle: bool = True) -> Iterator[ReplaySample]:
    """Offline dataset (§2.6/§3.7): iterate a fixed list of items forever."""
    rng = np.random.RandomState(seed)
    n = len(items)
    while True:
        idx = rng.randint(0, n, size=batch_size) if shuffle \
            else np.arange(batch_size) % n
        batch = [items[i] for i in idx]
        info = SampleInfo(np.asarray(idx, np.int64),
                          np.full(batch_size, 1.0 / n))
        yield ReplaySample(info, _stack(batch))
