"""Reverb-style rate limitation (§2.5 of the paper).

``SampleToInsertRatio`` enforces a target samples-per-insert (SPI) ratio with
an error tolerance: whichever side runs ahead *blocks* until the other
catches up.  The invariant maintained (and property-tested) is

    min_size_to_sample <= inserts         (before any sample)
    |samples - spi * (inserts - min_size)| <= tolerance   (while unblocked)

Implemented with a single condition variable, usable from many actor threads
and one or more learner threads simultaneously.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional


class RateLimiterTimeout(RuntimeError):
    pass


class RateLimiterInterrupt(RuntimeError):
    """A blocked waiter was woken by its ``interrupt`` predicate (e.g. the
    owning table was marked down for simulated failover) — nothing was
    counted; the caller decides whether to surface an error or re-wait."""


class RateLimiter:
    """Base: unlimited (MinSize behaviour with min_size_to_sample)."""

    def __init__(self, min_size_to_sample: int = 1):
        self.min_size_to_sample = max(int(min_size_to_sample), 1)
        self._lock = threading.Condition()
        self._inserts = 0
        self._samples = 0
        self._stopped = False

    # -- statistics --------------------------------------------------
    @property
    def inserts(self) -> int:
        return self._inserts

    @property
    def samples(self) -> int:
        return self._samples

    @property
    def stopped(self) -> bool:
        return self._stopped

    def stop(self):
        with self._lock:
            self._stopped = True
            self._lock.notify_all()

    # -- exact-resume serialization -----------------------------------
    def state_dict(self) -> dict:
        with self._lock:
            return {"inserts": self._inserts, "samples": self._samples}

    def load_state_dict(self, state: dict):
        with self._lock:
            self._inserts = int(state["inserts"])
            self._samples = int(state["samples"])
            self._lock.notify_all()

    # -- blocking predicates (override) -------------------------------
    def _can_insert(self) -> bool:
        return True

    def _can_sample(self) -> bool:
        return self._inserts >= self.min_size_to_sample

    # -- public api ----------------------------------------------------
    def notify_waiters(self):
        """Wake every blocked waiter so it re-evaluates its predicate —
        used by ``interrupt`` sources (they flip their flag, then call
        this; without it a parked waiter would sleep through the event)."""
        with self._lock:
            self._lock.notify_all()

    def await_can_insert(self, timeout: Optional[float] = None,
                         interrupt: Optional[Callable[[], bool]] = None):
        def _interrupted():
            return interrupt is not None and interrupt()

        with self._lock:
            if not self._lock.wait_for(
                    lambda: self._can_insert() or self._stopped
                    or _interrupted(), timeout):
                raise RateLimiterTimeout("insert blocked past timeout")
            if _interrupted():
                raise RateLimiterInterrupt("insert waiter interrupted")
            if self._stopped and not self._can_insert():
                raise RateLimiterTimeout("stopped")
            self._inserts += 1
            self._lock.notify_all()

    def rollback_sample(self):
        """Un-count one admitted sample: the table had no item to serve (a
        consuming selector drained it between admission and the draw)."""
        with self._lock:
            self._samples -= 1
            self._lock.notify_all()

    def await_can_sample(self, timeout: Optional[float] = None,
                         interrupt: Optional[Callable[[], bool]] = None):
        def _interrupted():
            return interrupt is not None and interrupt()

        with self._lock:
            if not self._lock.wait_for(
                    lambda: self._can_sample() or self._stopped
                    or _interrupted(), timeout):
                raise RateLimiterTimeout("sample blocked past timeout")
            if _interrupted():
                raise RateLimiterInterrupt("sample waiter interrupted")
            if self._stopped and not self._can_sample():
                raise RateLimiterTimeout("stopped")
            self._samples += 1
            self._lock.notify_all()

    def would_block_insert(self) -> bool:
        with self._lock:
            return not self._can_insert()

    def would_block_sample(self) -> bool:
        with self._lock:
            return not self._can_sample()


class SampleToInsertRatio(RateLimiter):
    """Block to keep samples ≈ spi * inserts within ±tolerance samples.

    Matches Reverb's SampleToInsertRatio semantics: let
    ``d = samples - spi * (inserts - min_size_to_sample)``; inserting is
    allowed while d > -tolerance (learner not too far behind), sampling is
    allowed while d < tolerance (learner not too far ahead) and the table has
    reached min size.
    """

    def __init__(self, samples_per_insert: float, min_size_to_sample: int,
                 error_buffer: float):
        super().__init__(min_size_to_sample)
        if samples_per_insert <= 0:
            raise ValueError("samples_per_insert must be > 0")
        self.spi = float(samples_per_insert)
        self.error_buffer = float(error_buffer)
        min_diff = -error_buffer
        if self.spi * self.min_size_to_sample + min_diff > 0:
            # ensure the first min_size inserts are never blocked
            self.error_buffer = self.spi * self.min_size_to_sample

    def _deficit(self) -> float:
        return self._samples - self.spi * (self._inserts - self.min_size_to_sample)

    def _can_insert(self) -> bool:
        # an insert is allowed if, AFTER it, the learner lags by at most the
        # error buffer: samples - spi*(inserts+1-min) >= -error_buffer.
        if self._inserts < self.min_size_to_sample:
            return True
        after = self._samples - self.spi * (self._inserts + 1
                                            - self.min_size_to_sample)
        return after >= -self.error_buffer

    def _can_sample(self) -> bool:
        if self._inserts < self.min_size_to_sample:
            return False
        return self._deficit() < self.error_buffer - 1


class MinSize(RateLimiter):
    """Only requirement: table has at least min_size items (no ratio)."""
