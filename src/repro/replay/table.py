"""Reverb-lite: an in-process, thread-safe replay table.

Items are arbitrary pytrees of numpy arrays (inserted by adders).  Selectors
implement Reverb's sampling distributions: Fifo, Lifo, Uniform, Prioritized.
Removal on overflow is FIFO.  The table enforces its RateLimiter on both
insert and sample paths, reproducing §2.5's blocking behaviour.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.replay.rate_limiter import (RateLimiter, RateLimiterInterrupt,
                                       RateLimiterTimeout, MinSize)
from repro.replay.selectors import Selector, Uniform
from repro.telemetry import registry as _telemetry


class Item:
    __slots__ = ("key", "data", "priority")

    def __init__(self, key: int, data: Any, priority: float):
        self.key = key
        self.data = data
        self.priority = priority


class Table:
    def __init__(self, name: str, capacity: int,
                 selector: Optional[Selector] = None,
                 rate_limiter: Optional[RateLimiter] = None):
        self.name = name
        self.capacity = int(capacity)
        self.selector = selector or Uniform()
        self.rate_limiter = rate_limiter or MinSize(1)
        self._lock = threading.Lock()
        self._items: Dict[int, Item] = {}
        # Insertion order for FIFO removal.  An OrderedDict (a doubly linked
        # list underneath) gives O(1) pop-oldest on eviction and O(1) removal
        # of arbitrary keys for consuming selectors, where a plain list was
        # O(n) per operation at full capacity.
        self._order: "OrderedDict[int, None]" = OrderedDict()
        self._next_key = 0
        # Simulated-death flag (repro.resilience.failover): while set, the
        # data path refuses calls so in-parent clients see the same outage
        # remote clients get from the torn-down courier server.
        self._down = threading.Event()
        # Block-time metrics are created on FIRST use, not here:
        # ``ShardedReplay.from_factory`` renames its shard tables after
        # construction, and the metric name must carry the final name.
        self._m_insert_block = None
        self._m_sample_block = None

    # --------------------------------------------------- service failover
    def mark_down(self):
        """Simulate abrupt service death: insert/sample/update_priorities
        raise ``ServiceUnavailable`` until ``mark_up``.  Metadata reads
        (``size``/``state_dict``) stay available — the failover watchdog
        and telemetry probes still need them.  Waiters already parked in
        the rate limiter are woken so they fail too, instead of sleeping
        through the outage holding the SPI coupling wedged."""
        self._down.set()
        self.rate_limiter.notify_waiters()

    def mark_up(self):
        self._down.clear()
        self.rate_limiter.notify_waiters()

    def _await_limiter(self, awaiter, timeout):
        """Run a limiter wait that fails over: while the table is down the
        wait raises ``ServiceUnavailable`` (via the interrupt hook) rather
        than parking a thread through the outage; a spurious wake-up that
        raced ``mark_up`` simply re-waits."""
        while True:
            try:
                return awaiter(timeout, interrupt=self._down.is_set)
            except RateLimiterInterrupt:
                self._check_up()

    def _check_up(self):
        if self._down.is_set():
            from repro.distributed.courier import ServiceUnavailable
            raise ServiceUnavailable(
                f"replay table {self.name!r} is down (simulated failure; "
                f"awaiting failover)")

    def _block_metrics(self):
        if self._m_insert_block is None:
            # "replay"/"replay/shard_i" names already carry the component
            # prefix; others ("queue", "demos") get it prepended.
            base = (self.name if self.name.split("/")[0] == "replay"
                    else f"replay/{self.name}")
            self._m_insert_block = _telemetry.histogram(
                f"{base}/insert_block_ms")
            self._m_sample_block = _telemetry.histogram(
                f"{base}/sample_block_ms")
        return self._m_insert_block, self._m_sample_block

    # ------------------------------------------------------------ insert
    def insert(self, data: Any, priority: float = 1.0,
               timeout: Optional[float] = None) -> int:
        self._check_up()
        m_insert, _ = self._block_metrics()
        if m_insert:
            t0 = time.monotonic()
            self._await_limiter(self.rate_limiter.await_can_insert, timeout)
            m_insert.observe((time.monotonic() - t0) * 1000.0)
        else:
            self._await_limiter(self.rate_limiter.await_can_insert, timeout)
        with self._lock:
            key = self._next_key
            self._next_key += 1
            self._items[key] = Item(key, data, priority)
            self._order[key] = None
            self.selector.insert(key, priority)
            while len(self._order) > self.capacity:
                evict, _ = self._order.popitem(last=False)
                self._items.pop(evict, None)
                self.selector.remove(evict)
            return key

    # ------------------------------------------------------------ sample
    def sample(self, batch_size: int = 1,
               timeout: Optional[float] = None) -> List[Tuple[Item, float]]:
        """Returns [(item, importance_weight_probability), ...]."""
        self._check_up()
        out = []
        _, m_sample = self._block_metrics()
        deadline = None if timeout is None else time.time() + timeout
        for _ in range(batch_size):
            while True:
                self._check_up()
                remaining = (None if deadline is None
                             else max(deadline - time.time(), 0.0))
                if m_sample:
                    t0 = time.monotonic()
                    self._await_limiter(self.rate_limiter.await_can_sample,
                                        remaining)
                    m_sample.observe((time.monotonic() - t0) * 1000.0)
                else:
                    self._await_limiter(self.rate_limiter.await_can_sample,
                                        remaining)
                with self._lock:
                    try:
                        key, prob = self.selector.sample()
                    except IndexError:
                        key = None   # admitted, but the table is empty
                    else:
                        out.append((self._items[key], prob))
                        if getattr(self.selector, "consumes", False):
                            self._items.pop(key, None)
                            self._order.pop(key, None)
                if key is not None:
                    break
                # The limiter admits on cumulative inserts, but a consuming
                # selector may have drained the table: un-count the sample
                # and wait for the next insert instead of crashing.
                self.rate_limiter.rollback_sample()
                if deadline is not None and time.time() >= deadline:
                    raise RateLimiterTimeout("sample blocked past timeout")
                time.sleep(0.001)
        return out

    def update_priorities(self, keys: Sequence[int], priorities: Sequence[float]):
        self._check_up()
        with self._lock:
            for k, p in zip(keys, priorities):
                if k in self._items:
                    self._items[k].priority = float(p)
                    self.selector.update(k, float(p))

    def size(self) -> int:
        with self._lock:
            return len(self._order)

    # ----------------------------------------------------- exact resume
    def state_dict(self) -> Dict[str, Any]:
        """A consistent snapshot of the table: items (in insertion order,
        so FIFO eviction resumes identically), priorities, the key counter,
        selector internals, and rate-limiter accounting."""
        with self._lock:
            try:
                selector_state = self.selector.state_dict()
            except NotImplementedError:
                selector_state = None
            return {
                "name": self.name,
                "capacity": self.capacity,
                "items": [(k, self._items[k].data, self._items[k].priority)
                          for k in self._order],
                "next_key": self._next_key,
                "selector": selector_state,
                "rate_limiter": self.rate_limiter.state_dict(),
            }

    def load_state_dict(self, state: Dict[str, Any]):
        """Restore into a freshly built table (same capacity/selector/
        limiter construction as at save time)."""
        with self._lock:
            self._items.clear()
            self._order.clear()
            for key, data, priority in state["items"]:
                key = int(key)
                self._items[key] = Item(key, data, float(priority))
                self._order[key] = None
            self._next_key = int(state["next_key"])
            if state.get("selector") is not None:
                self.selector.load_state_dict(state["selector"])
            else:
                # Best-effort rebuild for selectors without exact-resume
                # support: same membership and priorities, fresh RNG stream.
                for key, _, priority in state["items"]:
                    self.selector.insert(int(key), float(priority))
        self.rate_limiter.load_state_dict(state["rate_limiter"])

    @property
    def stopped(self) -> bool:
        return self.rate_limiter.stopped

    def stop(self):
        self.rate_limiter.stop()
