"""Agent heads: dueling Q (Wang et al. 2015), C51 categorical critic
(Bellemare et al. 2017), and tanh-Gaussian policies for continuous control."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.networks.mlp import mlp_apply, mlp_init


# ------------------------------------------------------------- dueling
def dueling_init(key, in_dim: int, hidden: int, num_actions: int):
    k1, k2 = jax.random.split(key)
    return {
        "value": mlp_init(k1, (in_dim, hidden, 1)),
        "advantage": mlp_init(k2, (in_dim, hidden, num_actions)),
    }


def dueling_apply(params, h):
    v = mlp_apply(params["value"], h)
    a = mlp_apply(params["advantage"], h)
    return v + a - jnp.mean(a, axis=-1, keepdims=True)


# ------------------------------------------------------------- C51
class CategoricalParams(NamedTuple):
    logits: jax.Array     # (..., num_atoms)
    atoms: jax.Array      # (num_atoms,)

    def mean(self) -> jax.Array:
        probs = jax.nn.softmax(self.logits, axis=-1)
        return jnp.sum(probs * self.atoms, axis=-1)


def categorical_init(key, in_dim: int, num_atoms: int = 51):
    return {"head": mlp_init(key, (in_dim, num_atoms))}


def categorical_apply(params, h, vmin: float, vmax: float,
                      num_atoms: int = 51) -> CategoricalParams:
    logits = mlp_apply(params["head"], h)
    atoms = jnp.linspace(vmin, vmax, num_atoms)
    return CategoricalParams(logits, atoms)


def l2_project(z_p, p, z_q):
    """Project distribution (z_p, p) onto support z_q (C51 projection Π)."""
    vmin, vmax = z_q[0], z_q[-1]
    d_pos = jnp.concatenate([z_q[1:], z_q[-1:]], 0) - z_q
    d_neg = z_q - jnp.concatenate([z_q[:1], z_q[:-1]], 0)
    z_p = jnp.clip(z_p, vmin, vmax)[..., None, :]      # (..., 1, n_p)
    z_q_ = z_q[..., :, None]                           # (n_q, 1)
    d_pos = jnp.where(d_pos == 0, 1.0, d_pos)[..., :, None]
    d_neg = jnp.where(d_neg == 0, 1.0, d_neg)[..., :, None]
    delta = z_p - z_q_                                 # (..., n_q, n_p)
    d_sign = (delta >= 0.0)
    delta_hat = jnp.where(d_sign, delta / d_pos, -delta / d_neg)
    p = p[..., None, :]
    return jnp.sum(jnp.clip(1.0 - delta_hat, 0.0, 1.0) * p, axis=-1)


# ------------------------------------------------------------- gaussian policy
def gaussian_policy_init(key, in_dim: int, hidden: int, action_dim: int):
    return {"net": mlp_init(key, (in_dim, hidden, 2 * action_dim))}


def gaussian_policy_apply(params, h, min_scale: float = 1e-3):
    out = mlp_apply(params["net"], h)
    mean, raw_scale = jnp.split(out, 2, axis=-1)
    scale = jax.nn.softplus(raw_scale) + min_scale
    return mean, scale
