"""LSTM core for recurrent agents (R2D2, §3.2)."""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp


class LSTMState(NamedTuple):
    h: jax.Array
    c: jax.Array


def lstm_init(key, in_dim: int, hidden: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    scale_i = in_dim ** -0.5
    scale_h = hidden ** -0.5
    return {
        "wi": (scale_i * jax.random.truncated_normal(
            k1, -2, 2, (in_dim, 4 * hidden))).astype(dtype),
        "wh": (scale_h * jax.random.truncated_normal(
            k2, -2, 2, (hidden, 4 * hidden))).astype(dtype),
        "b": jnp.zeros((4 * hidden,), dtype),
    }


def lstm_initial_state(hidden: int, batch: int = 1) -> LSTMState:
    return LSTMState(jnp.zeros((batch, hidden)), jnp.zeros((batch, hidden)))


def lstm_apply(params, x, state: LSTMState):
    """x: (batch, in_dim) one step. Returns (out, new_state)."""
    gates = x @ params["wi"] + state.h @ params["wh"] + params["b"]
    i, g, f, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * state.c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, LSTMState(h, c)


def lstm_unroll(params, xs, state: LSTMState):
    """xs: (T, batch, in_dim). Returns (outs (T, batch, H), final_state)."""
    def body(s, x):
        h, s = lstm_apply(params, x, s)
        return s, h
    final, outs = jax.lax.scan(body, state, xs)
    return outs, final


class LSTMNetwork:
    """MLP torso -> LSTM core -> linear head, for R2D2-style agents."""

    def __init__(self, torso_sizes: Sequence[int], hidden: int, out_dim: int):
        self.torso_sizes = tuple(torso_sizes)
        self.hidden = hidden
        self.out_dim = out_dim

    def init(self, key, in_dim: int):
        from repro.networks.mlp import mlp_init
        k1, k2, k3 = jax.random.split(key, 3)
        torso_in = (in_dim,) + self.torso_sizes
        return {
            "torso": mlp_init(k1, torso_in),
            "lstm": lstm_init(k2, self.torso_sizes[-1], self.hidden),
            "head": mlp_init(k3, (self.hidden, self.out_dim)),
        }

    def initial_state(self, batch: int = 1) -> LSTMState:
        return lstm_initial_state(self.hidden, batch)

    def apply(self, params, obs, state: LSTMState):
        from repro.networks.mlp import mlp_apply
        h = mlp_apply(params["torso"], obs, activate_final=True)
        h, state = lstm_apply(params["lstm"], h, state)
        return mlp_apply(params["head"], h), state

    def unroll(self, params, obs_seq, state: LSTMState):
        """obs_seq: (T, batch, feat)."""
        from repro.networks.mlp import mlp_apply
        h = mlp_apply(params["torso"], obs_seq, activate_final=True)
        outs, final = lstm_unroll(params["lstm"], h, state)
        return mlp_apply(params["head"], outs), final
