from repro.networks.mlp import mlp_init, mlp_apply, MLP  # noqa: F401
from repro.networks.lstm import lstm_init, lstm_apply, lstm_initial_state, LSTMNetwork  # noqa: F401
from repro.networks.heads import (  # noqa: F401
    dueling_init, dueling_apply, categorical_init, categorical_apply,
    gaussian_policy_init, gaussian_policy_apply, CategoricalParams)
