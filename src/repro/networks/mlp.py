"""Small MLP torsos for the classic-control agents (pure init/apply fns).

Convention: ``mlp_apply(params, x)`` expects ``x`` of shape (batch, features).
Agents flatten observations with :func:`flatten_obs` (spec-aware), so actors
can pass single unbatched observations and learners batched ones.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def flatten_obs(obs, spec_shape) -> jax.Array:
    """(..., *spec_shape) -> (batch, prod(spec_shape)); adds batch dim if absent."""
    obs = jnp.asarray(obs, jnp.float32)
    feat = int(np.prod(spec_shape)) if spec_shape else 1
    flat = obs.reshape(-1, feat) if obs.size != feat else obs.reshape(1, feat)
    return flat


def mlp_init(key, sizes: Sequence[int], dtype=jnp.float32):
    params = []
    for m, n in zip(sizes[:-1], sizes[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.truncated_normal(sub, -2, 2, (m, n)) * (m ** -0.5)
        params.append({"w": w.astype(dtype), "b": jnp.zeros((n,), dtype)})
    return params


def mlp_apply(params, x, activate_final: bool = False):
    h = jnp.asarray(x, jnp.float32)
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1 or activate_final:
            h = jax.nn.relu(h)
    return h


class MLP:
    def __init__(self, layer_sizes: Sequence[int]):
        self.layer_sizes = tuple(layer_sizes)

    def init(self, key, in_dim: int):
        return mlp_init(key, (in_dim,) + self.layer_sizes)

    apply = staticmethod(mlp_apply)
