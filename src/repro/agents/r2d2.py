"""R2D2 (§3.2): recurrent replay distributed DQN.

Sequences (with stored initial LSTM state + burn-in prefix), double
Q-learning over fixed-length sequences, prioritized by a convex combination
of mean and max absolute TD errors, n-step bootstrap targets.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.agents.common import (JaxLearner, LearnerState, fresh_copy,
                                 importance_weights)
from repro.builders import AgentBuilder, BuilderOptions
from repro.core.types import EnvironmentSpec
from repro.networks.lstm import LSTMNetwork, LSTMState
from repro.networks.mlp import flatten_obs
from repro.replay.dataset import ReplaySample


@dataclasses.dataclass
class R2D2Config:
    hidden: int = 64
    lstm_size: int = 64
    learning_rate: float = 1e-3
    discount: float = 0.99
    sequence_length: int = 16
    period: int = 8                  # overlapping sequences
    burn_in: int = 4
    batch_size: int = 32
    target_update_period: int = 100
    epsilon: float = 0.1
    min_replay_size: int = 100
    max_replay_size: int = 50_000
    samples_per_insert: float = 4.0
    priority_eta: float = 0.9        # max/mean TD mixing
    importance_beta: float = 0.6


def make_network(spec: EnvironmentSpec, cfg: R2D2Config) -> LSTMNetwork:
    num_actions = spec.actions.num_values
    net = LSTMNetwork((cfg.hidden,), cfg.lstm_size, num_actions)
    net.in_dim = int(np.prod(spec.observations.shape)) or 1
    return net


def make_learner(spec: EnvironmentSpec, cfg: R2D2Config, iterator: Iterator,
                 rng_key, priority_update_cb=None) -> JaxLearner:
    net = make_network(spec, cfg)
    opt = optim.adam(cfg.learning_rate, clip=40.0)
    params = net.init(rng_key, net.in_dim)
    state = LearnerState(params, fresh_copy(params), opt.init(params),
                         jnp.zeros((), jnp.int32))
    num_actions = spec.actions.num_values

    def q_over_sequence(params, obs_tm, lstm_state):
        """obs_tm: (T, B, feat) -> (T, B, A)."""
        q, _ = net.unroll(params, obs_tm, lstm_state)
        return q

    def loss_fn(params, target_params, sample: ReplaySample):
        seq = sample.data
        obs = seq["observation"].astype(jnp.float32)           # (B, T, ...)
        B, T = obs.shape[:2]
        obs_tm = jnp.swapaxes(obs.reshape(B, T, -1), 0, 1)     # (T, B, feat)
        actions = jnp.swapaxes(seq["action"].astype(jnp.int32), 0, 1)
        rewards = jnp.swapaxes(seq["reward"].astype(jnp.float32), 0, 1)
        discounts = jnp.swapaxes(
            seq["discount"].astype(jnp.float32) * cfg.discount, 0, 1)
        mask = jnp.swapaxes(seq["mask"].astype(jnp.float32), 0, 1)

        # stored initial state ("stale state"), burn-in re-warms it
        init_state = LSTMState(jnp.zeros((B, cfg.lstm_size)),
                               jnp.zeros((B, cfg.lstm_size)))
        if cfg.burn_in > 0:
            burn = obs_tm[:cfg.burn_in]
            _, warm = net.unroll(params, burn, init_state)
            _, warm_t = net.unroll(target_params, burn, init_state)
            warm = jax.tree.map(jax.lax.stop_gradient, warm)
            warm_t = jax.tree.map(jax.lax.stop_gradient, warm_t)
        else:
            warm = warm_t = init_state
        obs_l = obs_tm[cfg.burn_in:]
        act_l = actions[cfg.burn_in:]
        rew_l = rewards[cfg.burn_in:]
        disc_l = discounts[cfg.burn_in:]
        mask_l = mask[cfg.burn_in:]

        q = q_over_sequence(params, obs_l, warm)               # (L, B, A)
        q_target = q_over_sequence(target_params, obs_l, warm_t)
        # double Q with 1-step-within-sequence targets
        a_star = jnp.argmax(q[1:], axis=-1)
        next_v = jnp.take_along_axis(q_target[1:], a_star[..., None], -1)[..., 0]
        y = rew_l[:-1] + disc_l[:-1] * jax.lax.stop_gradient(next_v)
        q_taken = jnp.take_along_axis(q[:-1], act_l[:-1][..., None], -1)[..., 0]
        td = (y - q_taken) * mask_l[:-1]

        w = importance_weights(jnp.asarray(sample.info.probabilities),
                               cfg.importance_beta)
        loss = 0.5 * jnp.sum(w[None, :] * jnp.square(td)) / jnp.maximum(
            jnp.sum(mask_l[:-1]), 1.0)
        abs_td = jnp.abs(td)
        prio = cfg.priority_eta * jnp.max(abs_td, axis=0) + \
            (1 - cfg.priority_eta) * jnp.mean(abs_td, axis=0)
        return loss, prio

    def update(state: LearnerState, sample: ReplaySample):
        (loss, prio), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, state.target_params, sample)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = optim.apply_updates(state.params, updates)
        steps = state.steps + 1
        target = optim.periodic_update(params, state.target_params, steps,
                                       cfg.target_update_period)
        return (LearnerState(params, target, opt_state, steps),
                {"loss": loss}, prio)

    return JaxLearner(state, update, iterator,
                      priority_update_cb=priority_update_cb)


def make_behavior_policy(spec: EnvironmentSpec, cfg: R2D2Config,
                         epsilon=None):
    net = make_network(spec, cfg)
    eps = cfg.epsilon if epsilon is None else epsilon

    def policy(params, key, obs, lstm_state):
        obs = flatten_obs(obs, spec.observations.shape)
        q, new_state = net.apply(params, obs, lstm_state)
        greedy = jnp.argmax(q[0])
        rand = jax.random.randint(key, (), 0, spec.actions.num_values)
        explore = jax.random.uniform(key) < eps
        return jnp.where(explore, rand, greedy).astype(jnp.int32), new_state

    return policy


class R2D2Builder(AgentBuilder):
    def __init__(self, spec: EnvironmentSpec, cfg: R2D2Config = None,
                 seed: int = 0):
        cfg = cfg or R2D2Config()
        super().__init__(BuilderOptions(
            variable_update_period=10,
            min_observations=cfg.min_replay_size,
            observations_per_step=max(float(cfg.period), 1.0),
            batch_size=cfg.batch_size))
        self.spec = spec
        self.cfg = cfg
        self.seed = seed

    def make_replay(self):
        from repro import replay as r
        cfg = self.cfg
        if cfg.samples_per_insert > 0:
            limiter = r.SampleToInsertRatio(
                cfg.samples_per_insert, cfg.min_replay_size // cfg.period + 1,
                error_buffer=max(2 * cfg.samples_per_insert * cfg.batch_size, 100))
        else:
            limiter = r.MinSize(max(cfg.min_replay_size // cfg.period, 1))
        return r.Table("replay", cfg.max_replay_size, r.Prioritized(), limiter)

    def make_adder(self, table):
        from repro.adders.sequence import SequenceAdder
        return SequenceAdder(table, self.cfg.sequence_length,
                             period=self.cfg.period, priority=100.0)

    def make_dataset(self, table):
        from repro.replay import as_iterator
        return as_iterator(table, self.cfg.batch_size)

    def make_learner(self, iterator, priority_update_cb=None):
        return make_learner(self.spec, self.cfg, iterator,
                            jax.random.key(self.seed),
                            priority_update_cb=priority_update_cb)

    def make_policy(self, evaluation: bool = False):
        return make_behavior_policy(self.spec, self.cfg,
                                    epsilon=0.0 if evaluation else None)

    def make_actor(self, policy, variable_client, adder, seed: int = 0):
        from repro.core import RecurrentActor
        net = make_network(self.spec, self.cfg)
        return RecurrentActor(policy, lambda: net.initial_state(1),
                              variable_client, adder, rng_seed=seed)

    def make_batched_actor(self, policy, variable_client, adders,
                           seed: int = 0):
        from repro.core import BatchedRecurrentActor
        net = make_network(self.spec, self.cfg)
        return BatchedRecurrentActor(policy, lambda: net.initial_state(1),
                                     variable_client, adders, rng_seed=seed)
