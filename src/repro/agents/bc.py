"""Behaviour Cloning (§3.7): the offline baseline — supervised learning of
the action mapping from a fixed dataset of transitions."""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.agents.common import JaxLearner, LearnerState
from repro.builders import AgentBuilder, BuilderOptions
from repro.core.types import EnvironmentSpec
from repro.networks.mlp import flatten_obs, mlp_apply, mlp_init


@dataclasses.dataclass
class BCConfig:
    hidden: int = 64
    learning_rate: float = 1e-3
    batch_size: int = 64
    continuous: bool = False


def make_network(spec: EnvironmentSpec, cfg: BCConfig):
    obs_dim = int(np.prod(spec.observations.shape)) or 1
    if cfg.continuous:
        out = int(np.prod(spec.actions.shape)) or 1
    else:
        out = spec.actions.num_values

    def init(key):
        return mlp_init(key, (obs_dim, cfg.hidden, cfg.hidden, out))

    def apply(params, obs):
        return mlp_apply(params, obs)

    return init, apply, obs_dim, out


def make_learner(spec: EnvironmentSpec, cfg: BCConfig, iterator: Iterator,
                 rng_key) -> JaxLearner:
    init, apply, obs_dim, out = make_network(spec, cfg)
    opt = optim.adam(cfg.learning_rate)
    params = init(rng_key)
    state = LearnerState(params, (), opt.init(params), jnp.zeros((), jnp.int32))

    def loss_fn(params, t):
        obs = flatten_obs(t.observation, spec.observations.shape)
        pred = apply(params, obs)
        if cfg.continuous:
            a = t.action.reshape(obs.shape[0], -1).astype(jnp.float32)
            return jnp.mean(jnp.square(jnp.tanh(pred) - a))
        logp = jax.nn.log_softmax(pred)
        a = t.action.astype(jnp.int32)
        return -jnp.mean(jnp.take_along_axis(logp, a[:, None], -1))

    def update(state: LearnerState, sample):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, sample.data)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = optim.apply_updates(state.params, updates)
        return (LearnerState(params, (), opt_state, state.steps + 1),
                {"loss": loss}, None)

    return JaxLearner(state, update, iterator)


def make_eval_policy(spec: EnvironmentSpec, cfg: BCConfig):
    _, apply, _, _ = make_network(spec, cfg)

    def policy(params, key, obs):
        obs = flatten_obs(obs, spec.observations.shape)
        out = apply(params, obs)[0]
        if cfg.continuous:
            return jnp.tanh(out)
        return jnp.argmax(out).astype(jnp.int32)

    return policy


class BCBuilder(AgentBuilder):
    """Offline builder (§2.6): learns from a fixed transition dataset.

    There is no insertion path — ``make_replay`` returns a table pre-loaded
    with the dataset and ``make_adder`` returns None.  Actors built from it
    are pure evaluators of the cloned policy.
    """

    def __init__(self, spec: EnvironmentSpec, dataset, cfg: BCConfig = None,
                 seed: int = 0):
        cfg = cfg or BCConfig()
        super().__init__(BuilderOptions(
            variable_update_period=1,
            min_observations=0,
            observations_per_step=1.0,
            batch_size=cfg.batch_size,
            offline=True))
        self.spec = spec
        self.cfg = cfg
        self.seed = seed
        self.dataset = list(dataset)
        if not self.dataset:
            raise ValueError("BCBuilder needs a non-empty dataset")

    def make_replay(self):
        from repro.replay import MinSize, Table, Uniform
        table = Table("dataset", len(self.dataset), Uniform(self.seed),
                      MinSize(1))
        for item in self.dataset:
            table.insert(item)
        return table

    def make_adder(self, table):
        return None              # offline: nothing writes to the dataset

    def make_dataset(self, table):
        from repro.replay import as_iterator
        return as_iterator(table, self.cfg.batch_size)

    def make_learner(self, iterator, priority_update_cb=None):
        return make_learner(self.spec, self.cfg, iterator,
                            jax.random.key(self.seed))

    def make_policy(self, evaluation: bool = False):
        return make_eval_policy(self.spec, self.cfg)

    def make_actor(self, policy, variable_client, adder, seed: int = 0):
        from repro.core import FeedForwardActor
        return FeedForwardActor(policy, variable_client, adder, rng_seed=seed)
