"""Agent assembly: the same ``AgentBuilder`` yields the single-process agent
(§2.2) and the distributed program (§2.4) — Acme's central design claim.

Builders implement the typed ``repro.builders.AgentBuilder`` contract; the
execution schedule comes from their frozen ``BuilderOptions`` (no duck-typed
attribute probing).  These two assembly functions are the low-level layer;
``repro.experiments`` wraps them in the config-driven run API that examples,
benchmarks, and tests use.
"""
from __future__ import annotations

import itertools
import threading
from typing import Optional

from repro.builders import AgentBuilder
from repro.core import Agent, Counter, EnvironmentLoop, VariableClient
from repro.distributed.program import LocalLauncher, Program
from repro.replay import PrefetchingDataset, ShardedReplay, make_replay_shards


def _resolve(explicit, default):
    return default if explicit is None else explicit


def _effective_shards(options, num_replay_shards):
    """Offline builders preload their fixed dataset in make_replay —
    sharding would duplicate it per shard (and there is no insert
    throughput to scale), so they always keep a single table."""
    if options.offline:
        return 1
    return _resolve(num_replay_shards, options.num_replay_shards)


def make_agent(builder: AgentBuilder, seed: int = 0,
               num_replay_shards: Optional[int] = None) -> Agent:
    """Synchronous single-process agent: actor and learner in lockstep.

    Sharded replay is honoured here too; prefetching is not — the lockstep
    schedule relies on sampling (and its rate-limiter accounting) happening
    synchronously inside the learner step.
    """
    options = builder.options
    num_shards = _effective_shards(options, num_replay_shards)
    table = make_replay_shards(builder.make_replay, num_shards)
    adder = builder.make_adder(table)
    iterator = builder.make_dataset(table)
    learner = builder.make_learner(
        iterator, priority_update_cb=table.update_priorities)
    client = VariableClient(learner,
                            update_period=options.variable_update_period)
    actor = builder.make_actor(builder.make_policy(evaluation=False),
                               client, adder, seed)
    consuming = table.selector.consumes

    def can_step():
        if table.rate_limiter.would_block_sample():
            return False
        return table.size() >= options.batch_size if consuming else True

    return Agent(actor, learner,
                 min_observations=options.min_observations,
                 observations_per_step=options.observations_per_step,
                 can_step=can_step)


class _LearnerWorker:
    """Learner node: run learner steps until stopped (rate limiter blocks us
    when we get ahead of the actors — §2.5)."""

    def __init__(self, learner, max_steps: Optional[int] = None):
        self.learner = learner
        self.max_steps = max_steps
        self._stop = threading.Event()

    def run(self):
        for i in itertools.count():
            if self._stop.is_set():
                return
            if self.max_steps is not None and i >= self.max_steps:
                return
            try:
                self.learner.step()
            except Exception:
                if self._stop.is_set():
                    return
                raise

    def stop(self):
        self._stop.set()

    def get_variables(self, names=()):
        return self.learner.get_variables(names)


class _ActorWorker:
    """Actor node: its own environment instance + loop (Fig 4)."""

    def __init__(self, env_factory, builder, variable_source, counter,
                 table, seed: int, max_episodes: Optional[int] = None):
        self.env = env_factory(seed)
        client = VariableClient(
            variable_source,
            update_period=builder.options.variable_update_period)
        adder = builder.make_adder(table)
        actor = builder.make_actor(builder.make_policy(evaluation=False),
                                   client, adder, seed)
        self.loop = EnvironmentLoop(self.env, actor, counter=counter,
                                    label="actor")
        self.max_episodes = max_episodes
        self._stop = threading.Event()

    def run(self):
        self.loop.run(num_episodes=self.max_episodes,
                      should_stop=self._stop.is_set)

    def stop(self):
        self._stop.set()


class DistributedAgent:
    """Handle onto a launched distributed program."""

    def __init__(self, program, launcher, learner, table, counter,
                 dataset=None):
        self.program = program
        self.launcher = launcher
        self.learner = learner
        self.table = table
        self.counter = counter
        self.dataset = dataset

    def stop(self):
        # launcher first: it marks the shutdown as user-initiated (so late
        # rate-limiter wakeups are noise, not worker errors) and stops every
        # node, including the replay shards.
        self.launcher.stop()
        self.table.stop()
        if self.dataset is not None and hasattr(self.dataset, "stop"):
            self.dataset.stop()
        self.launcher.join(timeout=10)


class _EvaluatorWorker:
    """Background evaluator (§4.2): an actor with NO adder that periodically
    pulls weights and logs episode returns against learner steps."""

    def __init__(self, env_factory, builder, variable_source, counter,
                 seed: int, period_s: float = 1.0):
        self.env = env_factory(seed)
        client = VariableClient(variable_source, update_period=1)
        actor = builder.make_actor(builder.make_policy(evaluation=True),
                                   client, adder=None, seed=seed)
        self.loop = EnvironmentLoop(self.env, actor, counter=counter,
                                    label="evaluator", should_update=True)
        self.period_s = period_s
        self.returns = []
        self._stop = threading.Event()

    def run(self):
        while not self._stop.is_set():
            result = self.loop.run_episode()
            self.returns.append(result["episode_return"])
            self._stop.wait(self.period_s)

    def stop(self):
        self._stop.set()


def make_distributed_agent(builder: AgentBuilder, env_factory,
                           num_actors: int,
                           seed: int = 0,
                           max_learner_steps: Optional[int] = None,
                           with_evaluator: bool = False,
                           num_replay_shards: Optional[int] = None,
                           prefetch_size: Optional[int] = None) -> DistributedAgent:
    """Replicated actors + one learner + replay (+ background evaluator),
    on a Launchpad-lite graph — Fig 4 of the paper.

    With ``num_replay_shards > 1`` the replay service is a ``ShardedReplay``
    built from the builder's own ``make_replay`` — one replay node per shard
    is placed in the program graph.  With ``prefetch_size > 0`` the learner
    consumes batches through a ``PrefetchingDataset`` instead of the
    synchronous dataset.  Both default to the builder's ``BuilderOptions``.
    """
    program = Program("distributed_agent")
    counter = Counter()
    options = builder.options
    num_shards = _effective_shards(options, num_replay_shards)
    prefetch = _resolve(prefetch_size, options.prefetch_size)

    table = make_replay_shards(builder.make_replay, num_shards)
    iterator = builder.make_dataset(table)
    if prefetch > 0:
        iterator = PrefetchingDataset.over_iterator(iterator,
                                                    prefetch_size=prefetch)
    learner = builder.make_learner(
        iterator, priority_update_cb=table.update_priorities)
    worker = _LearnerWorker(learner, max_steps=max_learner_steps)

    # replay placement: one node per shard (what a multi-host launcher would
    # schedule onto separate replay servers), plus the routing front-end.
    if isinstance(table, ShardedReplay):
        for i, shard in enumerate(table.shards):
            program.add_node(f"replay/shard_{i}", lambda s=shard: s)
    program.add_node("replay", lambda: table)
    learner_handle = program.add_node("learner", lambda: worker,
                                      is_worker=True)
    for i in range(num_actors):
        program.add_node(
            f"actor_{i}", _ActorWorker, env_factory, builder, learner_handle,
            counter, table, seed + 1000 * (i + 1), is_worker=True)
    if with_evaluator:
        program.add_node("evaluator", _EvaluatorWorker, env_factory, builder,
                         learner_handle, counter, seed + 999_999,
                         is_worker=True)

    launcher = LocalLauncher(program).launch()
    agent = DistributedAgent(program, launcher, learner, table, counter,
                             dataset=iterator if prefetch > 0 else None)
    if with_evaluator:
        agent.evaluator = program.resolve("evaluator")
    return agent
