"""Agent assembly: the same ``AgentBuilder`` yields the single-process agent
(§2.2) and the distributed program (§2.4) — Acme's central design claim.

Builders implement the typed ``repro.builders.AgentBuilder`` contract; the
execution schedule comes from their frozen ``BuilderOptions`` (no duck-typed
attribute probing).  ``make_distributed_agent`` emits a backend-agnostic
``Program``: replay shards, the counter, and the learner are *service* nodes
(courier-servable), actors are a replicated *worker* pool — so the graph
runs unchanged on the ``local`` (threads) or ``multiprocess`` (one OS
process per worker, RPC edges) launcher backend.  These assembly functions
are the low-level layer; ``repro.experiments`` wraps them in the
config-driven run API that examples, benchmarks, and tests use.
"""
from __future__ import annotations

import threading
from typing import List, Optional

from repro.builders import AgentBuilder
from repro.core import (Agent, Counter, EnvironmentLoop,
                        INFERENCE_INTERFACE, InferenceServer, VariableClient,
                        VectorizedEnvironmentLoop)
from repro.core.inference import policy_is_feed_forward
from repro.distributed.launchers import JoinTimeout, get_launcher
from repro.distributed.program import Program, Replica
from repro.envs.vector import VectorEnv
from repro.learners import (ASYNC_PARAM_SERVICE_INTERFACE,
                            PARAM_SERVER_INTERFACE, AsyncParameterService,
                            LearnerReplicaWorker, MultiLearner,
                            ParameterServer)
from repro.replay import (PrefetchingDataset, ShardedReplay, ShardWriter,
                          make_replay_shards)
from repro.replay.service import REPLAY_INTERFACE
from repro.telemetry import (HUB_INTERFACE, MetricsHub, MetricsPusher,
                             WorkerTelemetry)
from repro.telemetry import registry as _telemetry


def _resolve(explicit, default):
    return default if explicit is None else explicit


def _register_replay_probe(table):
    """Export replay occupancy as snapshot-time gauges (no-op while
    telemetry is disabled): ``replay/size``, ``replay/inserts``, … plus
    ``replay/shard_i/<stat>`` per shard when the table is a
    ``ShardedReplay`` (its ``stats()`` carries a ``per_shard`` list)."""
    stats_fn = getattr(table, "stats", None)
    if callable(stats_fn):
        def probe_fn():
            out = {}
            for k, v in stats_fn().items():
                if k == "per_shard":
                    for i, shard_stats in enumerate(v):
                        for sk, sv in shard_stats.items():
                            if sk != "name":
                                out[f"shard_{i}/{sk}"] = sv
                else:
                    out[k] = v
            return out
    else:
        def probe_fn():
            return {"size": table.size()}
    _telemetry.probe("replay", probe_fn)


def _effective_shards(options, num_replay_shards):
    """Offline builders preload their fixed dataset in make_replay —
    sharding would duplicate it per shard (and there is no insert
    throughput to scale), so they always keep a single table."""
    if options.offline:
        return 1
    return _resolve(num_replay_shards, options.num_replay_shards)


def _effective_replicas(options, num_learner_replicas):
    """(num_replicas, engaged): multi-learner machinery is engaged when the
    caller asked for it explicitly — even num_learner_replicas=1, which the
    parity net proves equivalent to the plain path — or the builder's
    options default to more than one replica.  Offline builders keep the
    plain learner (their fixed dataset has no shards to give replicas
    affinity over); explicitly asking them for replicas is a config-time
    error, not a silent downgrade."""
    if options.offline:
        if num_learner_replicas is not None and num_learner_replicas > 1:
            raise ValueError(
                f"offline builders cannot run num_learner_replicas="
                f"{num_learner_replicas}: the fixed dataset has no replay "
                f"shards to give replicas affinity over")
        return 1, False
    replicas = _resolve(num_learner_replicas, options.num_learner_replicas)
    engaged = (num_learner_replicas is not None
               or options.num_learner_replicas > 1)
    return replicas, engaged


def _effective_sync(options, learner_sync):
    """Resolved learner sync mode; ``"async"`` rejects offline builders for
    the same reason explicit replicas do (no shards, no replica streams)."""
    sync = _resolve(learner_sync, options.learner_sync)
    if sync not in ("barrier", "quorum", "async"):
        raise ValueError(f"learner_sync must be 'barrier', 'quorum' or "
                         f"'async', got {sync!r}")
    if sync == "async" and options.offline:
        raise ValueError(
            "offline builders cannot run learner_sync='async': the fixed "
            "dataset has no replay shards to give replicas affinity over")
    return sync


def _effective_routing(options, replay_routing):
    routing = _resolve(replay_routing, options.replay_routing)
    if routing not in ("round_robin", "hash", "affinity"):
        raise ValueError(f"replay_routing must be 'round_robin', 'hash' or "
                         f"'affinity', got {routing!r}")
    return routing


def _replica_sharding(options, num_replay_shards, num_replicas):
    """Shard count for a multi-learner run: replica i consumes shard i
    exclusively (shard affinity), so the counts must match — an unset/1
    shard count follows the replica count."""
    shards = _effective_shards(options, num_replay_shards)
    if num_replicas <= 1:
        return shards
    if shards == 1:
        return num_replicas
    if shards != num_replicas:
        raise ValueError(
            f"num_learner_replicas={num_replicas} needs one replay shard "
            f"per replica (shard affinity), got num_replay_shards={shards}; "
            f"leave num_replay_shards unset or make the counts equal")
    return shards


def _make_replica_learners(builder, table, num_replicas, prefetch=0):
    """One learner per replay shard, each consuming only its own shard's
    dataset (local shard keys, so priority updates route shard-directly)
    — optionally through a per-replica ``PrefetchingDataset``.  Returns
    (learners, datasets, shards); datasets[i] is None unless prefetching.
    """
    if num_replicas > 1:
        if not isinstance(table, ShardedReplay) \
                or table.num_shards != num_replicas:
            raise ValueError(
                f"{num_replicas} learner replicas need a ShardedReplay "
                f"with exactly {num_replicas} shards, got {table!r}")
        shards = list(table.shards)
    else:
        shards = [table]
    learners, datasets = [], []
    for shard in shards:
        iterator = builder.make_dataset(shard)
        dataset = None
        if prefetch > 0:
            dataset = PrefetchingDataset.over_iterator(
                iterator, prefetch_size=prefetch)
            iterator = dataset
        learners.append(builder.make_learner(
            iterator, priority_update_cb=shard.update_priorities))
        datasets.append(dataset)
    return learners, datasets, shards


def make_agent(builder: AgentBuilder, seed: int = 0,
               num_replay_shards: Optional[int] = None,
               num_envs: Optional[int] = None,
               num_learner_replicas: Optional[int] = None,
               learner_average_period: Optional[int] = None,
               learner_sync: Optional[str] = None,
               replay_routing: Optional[str] = None,
               telemetry: Optional[bool] = None) -> Agent:
    """Synchronous single-process agent: actor and learner in lockstep.

    Sharded replay is honoured here too; prefetching is not — the lockstep
    schedule relies on sampling (and its rate-limiter accounting) happening
    synchronously inside the learner step.  With ``num_envs > 1`` the actor
    is the builder's BATCHED actor fanning out to one adder per env — drive
    it with a ``VectorEnv`` + ``VectorizedEnvironmentLoop``.

    ``num_learner_replicas`` routes learning through a ``MultiLearner``:
    one replica per replay shard, stepped sequentially round-robin by the
    agent's schedule, with parameter averaging every
    ``learner_average_period`` per-replica steps.  ``learner_sync="async"``
    swaps the in-line barrier merge for an ``AsyncParameterService``: each
    replica pushes/pulls at its own period boundary (and engages the
    multi-learner machinery even at one replica — the parity case).  A
    sequential schedule has no stragglers, so ``"quorum"`` degenerates to
    ``"barrier"`` here.

    ``replay_routing="affinity"`` gives each env's adder a ``ShardWriter``
    onto its assigned shard (``env e -> shard e % num_shards``) instead of
    routing every insert through the front-end cursor.
    """
    options = builder.options
    # (Re)configure the process registry BEFORE any component construction:
    # learners/engines/tables register their metrics and probes in __init__.
    _telemetry.configure(enabled=_resolve(telemetry, options.telemetry),
                         node="local")
    sync = _effective_sync(options, learner_sync)
    routing = _effective_routing(options, replay_routing)
    replicas, multi = _effective_replicas(options, num_learner_replicas)
    multi = multi or sync == "async"
    period = _resolve(learner_average_period,
                      options.learner_average_period)
    num_shards = (_replica_sharding(options, num_replay_shards, replicas)
                  if multi else _effective_shards(options, num_replay_shards))
    num_envs = _resolve(num_envs, options.num_envs_per_actor)
    table = make_replay_shards(builder.make_replay, num_shards,
                               routing=routing)
    _register_replay_probe(table)
    shard_tables = None
    if multi:
        replica_learners, _, shard_tables = _make_replica_learners(
            builder, table, replicas)
        if sync == "async":
            learner = MultiLearner(
                replica_learners, average_period=period,
                async_service=AsyncParameterService(replicas))
        else:
            learner = MultiLearner(replica_learners, average_period=period)
    else:
        iterator = builder.make_dataset(table)
        learner = builder.make_learner(
            iterator, priority_update_cb=table.update_priorities)
    client = VariableClient(learner,
                            update_period=options.variable_update_period)
    policy = builder.make_policy(evaluation=False)
    affine = routing == "affinity" and isinstance(table, ShardedReplay)
    if num_envs > 1:
        if affine:
            adders = [
                builder.make_adder(table.shard_view(e % table.num_shards))
                for e in range(num_envs)]
        else:
            adders = [builder.make_adder(table) for _ in range(num_envs)]
        actor = builder.make_batched_actor(policy, client, adders, seed)
    else:
        sink = table.shard_view(0) if affine else table
        actor = builder.make_actor(policy, client,
                                   builder.make_adder(sink), seed)
    consuming = table.selector.consumes

    if multi and replicas > 1:
        def can_step():
            # a sequential multi-learner step samples ONE shard — the
            # round-robin cursor's — so gate on that shard: the aggregate
            # view can satisfy batch_size while the cursor's shard cannot
            # serve a batch, which would hang the lockstep loop inside a
            # blocking sample (no actor runs while the learner steps).
            shard = shard_tables[learner.next_replica]
            if shard.rate_limiter.would_block_sample():
                return False
            return shard.size() >= options.batch_size if consuming else True
    else:
        def can_step():
            if table.rate_limiter.would_block_sample():
                return False
            return table.size() >= options.batch_size if consuming else True

    agent = Agent(actor, learner,
                  min_observations=options.min_observations,
                  observations_per_step=options.observations_per_step,
                  can_step=can_step)
    # The table is otherwise internal to assembly; run-wide checkpointing
    # (repro.resilience) reaches replay contents through the agent.
    agent.table = table
    return agent


class _DeferredBuilder:
    """Picklable stand-in for an AgentBuilder: ships ``(factory, spec)``
    across a process boundary and rebuilds the builder child-side (builder
    instances may hold unpicklable state; their factories must not)."""

    def __init__(self, factory, spec):
        self.factory = factory
        self.spec = spec

    def build(self) -> AgentBuilder:
        return self.factory(self.spec)


def _builder_of(builder):
    return builder.build() if isinstance(builder, _DeferredBuilder) \
        else builder


class _LearnerWorker(LearnerReplicaWorker):
    """Single-learner node: a service/worker hybrid — steps SGD until
    stopped (the rate limiter blocks us when we get ahead of the actors,
    §2.5) and serves ``get_variables`` to the actor pool (over courier when
    actors live in other processes).  The degenerate one-replica,
    no-rendezvous case of ``LearnerReplicaWorker`` — one run loop, one set
    of stop/exception semantics."""

    def __init__(self, learner, max_steps: Optional[int] = None):
        super().__init__(learner, param_server=None, max_steps=max_steps)


class _ResilientActor:
    """Graceful degradation during a service's restart window.

    The OUTERMOST actor wrapper in workers running under a
    ``RestartPolicy``: an add that hits an unreachable replay service is
    skipped (the transition is lost, counted in
    ``resilience/skipped_adds``) and a weight sync that cannot reach the
    learner keeps acting on the ``VariableClient``'s cached params
    (``resilience/skipped_updates``) — instead of the ``ConnectionError``
    killing the worker and burning a restart budget that belongs to real
    failures.  Catches ``ConnectionError`` so both transport-level
    unavailability (``ServiceUnavailable`` after the reconnect deadline)
    and the application-level down-marker a killed service raises are
    absorbed uniformly.  ``select_action`` is NOT wrapped: with no action
    there is no step to degrade to.
    """

    def __init__(self, actor):
        self._actor = actor
        self._m_adds = None
        self._m_updates = None

    def _skip(self, attr, name):
        metric = getattr(self, attr)
        if metric is None:
            if not _telemetry.enabled():
                return
            metric = _telemetry.counter(name)
            setattr(self, attr, metric)
        metric.inc()

    def observe_first(self, *args, **kwargs):
        try:
            return self._actor.observe_first(*args, **kwargs)
        except ConnectionError:
            self._skip("_m_adds", "resilience/skipped_adds")

    def observe(self, *args, **kwargs):
        try:
            return self._actor.observe(*args, **kwargs)
        except ConnectionError:
            self._skip("_m_adds", "resilience/skipped_adds")

    def update(self, *args, **kwargs):
        try:
            return self._actor.update(*args, **kwargs)
        except ConnectionError:
            self._skip("_m_updates", "resilience/skipped_updates")

    def __getattr__(self, name):
        return getattr(self._actor, name)


class _ActorWorker:
    """Actor node: its own environment instance(s) + loop (Fig 4).  Every
    collaborator arrives as a handle (in-memory or courier RemoteHandle) —
    this class cannot tell which backend it runs under.

    ``num_envs > 1`` turns the node into a vectorized acting worker: a
    ``VectorEnv`` of N auto-resetting envs driven by the builder's batched
    actor (one policy dispatch per N transitions), each env writing through
    its own adder.  ``inference`` (a handle to an ``InferenceServer``)
    switches policy evaluation to SEED-style RPC — the worker then holds no
    weights and never polls the learner.

    ``chaos`` (a ``repro.resilience.KillSchedule``) wraps the actor so the
    process hard-kills itself after N environment steps; ``rpc_chaos`` (the
    run's ``ChaosPolicy``) installs a courier-layer fault injector in this
    worker's process.  Both are picklable and resolved per replica at
    assembly time — the chaos acceptance tests drive them.

    ``shard_tables`` (a list of per-shard handles, one per ``replay/shard_i``
    node) switches the worker to shard-affine routing: env ``e`` of actor
    ``actor_index`` writes through its own ``ShardWriter`` straight to shard
    ``(actor_index * num_envs + e) % num_shards`` — zero front-end
    coordination, and the global keys it observes stay interchangeable with
    the front-end's (priority updates route back by key).
    """

    def __init__(self, env_factory, builder, variable_source, counter,
                 table, seed: int, max_episodes: Optional[int] = None,
                 num_envs: int = 1, inference=None, telemetry=None,
                 chaos=None, rpc_chaos=None, rpc_retry=None,
                 resilient: bool = False, actor_index: int = 0,
                 shard_tables=None):
        # FIRST: in a spawn child this configures the process registry, so
        # everything constructed below (actors, engines, courier clients)
        # records into it.  Under the local launcher the parent already
        # configured this process and install() is a no-op.
        self._telemetry_pusher = (telemetry.install()
                                  if telemetry is not None else None)
        if rpc_chaos is not None:
            # Install BEFORE any courier client exists in this process so
            # every RPC the worker makes passes through the injector.
            injector = rpc_chaos.rpc_injector()
            if injector is not None:
                injector.install()
        if rpc_retry is not None:
            # Likewise process-global: every courier client in this worker
            # retries under the run's RetryConfig.
            from repro.distributed import courier
            courier.set_retry_config(rpc_retry)
        builder = _builder_of(builder)
        options = builder.options
        num_envs = max(int(num_envs), 1)

        def env_sink(e):
            # the table each env's adder writes to: its affine shard when
            # shard handles were wired in, the routing front-end otherwise
            if shard_tables is not None:
                idx = (actor_index * num_envs + e) % len(shard_tables)
                return ShardWriter(shard_tables[idx], idx, len(shard_tables))
            return table

        if inference is not None:
            if num_envs > 1:
                adders = [builder.make_adder(env_sink(e))
                          for e in range(num_envs)]
                actor = builder.make_inference_actor(inference, adders=adders)
            else:
                actor = builder.make_inference_actor(
                    inference, adder=builder.make_adder(env_sink(0)))
        else:
            client = VariableClient(variable_source, update_period=1)
            policy = builder.make_policy(evaluation=False)
            if num_envs > 1:
                adders = [builder.make_adder(env_sink(e))
                          for e in range(num_envs)]
                actor = builder.make_batched_actor(policy, client, adders,
                                                   seed)
            else:
                actor = builder.make_actor(
                    policy, client, builder.make_adder(env_sink(0)), seed)
        if chaos is not None:
            # no-op when the schedule has disarmed (max_kills delivered)
            actor = chaos.wrap(actor)
        if resilient:
            # outermost, OUTSIDE the chaos wrapper: degradation absorbs
            # ConnectionErrors from below without hiding the kill schedule
            actor = _ResilientActor(actor)
        # weight-sync cadence lives in the LOOP (update_period in env steps /
        # ticks); the client fetches on every poke it does receive.  A tick
        # of the vectorized loop covers num_envs transitions, so the tick
        # period shrinks accordingly.
        update_period = max(options.variable_update_period // num_envs, 1)
        if num_envs > 1:
            self.env = VectorEnv(env_factory, num_envs, seed=seed)
            self.loop = VectorizedEnvironmentLoop(
                self.env, actor, counter=counter, label="actor",
                update_period=update_period)
        else:
            self.env = env_factory(seed)
            self.loop = EnvironmentLoop(self.env, actor, counter=counter,
                                        label="actor",
                                        update_period=update_period)
        self.max_episodes = max_episodes
        self._stop = threading.Event()

    def run(self):
        try:
            self.loop.run(num_episodes=self.max_episodes,
                          should_stop=self._stop.is_set)
        finally:
            if self._telemetry_pusher is not None:
                self._telemetry_pusher.stop()   # final push to the hub

    def stop(self):
        self._stop.set()


class ReturnsLog:
    """Append-only episode-return log a remote evaluator reports into (the
    parent cannot reach into a child process to read a plain list)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items: List[float] = []

    def append(self, value: float):
        with self._lock:
            self._items.append(float(value))

    def items(self) -> List[float]:
        with self._lock:
            return list(self._items)


class _EvaluatorWorker:
    """Background evaluator (§4.2): an actor with NO adder that periodically
    pulls weights and logs episode returns against learner steps."""

    def __init__(self, env_factory, builder, variable_source, counter,
                 seed: int, returns_log=None, period_s: float = 1.0,
                 telemetry=None):
        self._telemetry_pusher = (telemetry.install()
                                  if telemetry is not None else None)
        builder = _builder_of(builder)
        self.env = env_factory(seed)
        client = VariableClient(variable_source, update_period=1)
        actor = builder.make_actor(builder.make_policy(evaluation=True),
                                   client, adder=None, seed=seed)
        self.loop = EnvironmentLoop(self.env, actor, counter=counter,
                                    label="evaluator", should_update=True)
        self.period_s = period_s
        self.returns: List[float] = []
        self._log = returns_log
        self._stop = threading.Event()

    def run(self):
        try:
            while not self._stop.is_set():
                result = self.loop.run_episode()
                self.returns.append(result["episode_return"])
                if self._log is not None:
                    self._log.append(result["episode_return"])
                self._stop.wait(self.period_s)
        finally:
            if self._telemetry_pusher is not None:
                self._telemetry_pusher.stop()   # final push to the hub

    def stop(self):
        self._stop.set()


class DistributedAgent:
    """Handle onto a launched distributed program."""

    def __init__(self, program, launcher, learner, table, counter,
                 datasets=(), eval_log=None, inference_server=None,
                 telemetry_hub=None, telemetry_pusher=None):
        self.program = program
        self.launcher = launcher
        self.learner = learner
        self.table = table
        self.counter = counter
        self.datasets = [d for d in datasets if d is not None]
        self.eval_log = eval_log
        self.inference_server = inference_server
        self.telemetry_hub = telemetry_hub
        self._telemetry_pusher = telemetry_pusher

    def evaluator_returns(self) -> List[float]:
        """Episode returns reported by the evaluator node (works for both
        backends; the evaluator may live in another process)."""
        return self.eval_log.items() if self.eval_log is not None else []

    def learner_stats(self) -> Optional[dict]:
        """Per-replica step counts + averaging rounds when the learner is a
        multi-learner (``result.extras['learners']``); None otherwise."""
        stats = getattr(self.learner, "stats", None)
        return stats() if callable(stats) else None

    def stop(self):
        # launcher first: it marks the shutdown as user-initiated (so late
        # rate-limiter wakeups are noise, not worker errors) and stops every
        # node, including the replay shards.
        self.launcher.stop()
        self.table.stop()
        for dataset in self.datasets:
            # close (not just stop): sampler threads are joined and the
            # queue drained, so sequential runs in one process cannot
            # accumulate leaked prefetch threads.
            if hasattr(dataset, "close"):
                dataset.close()
            elif hasattr(dataset, "stop"):
                dataset.stop()
        try:
            self.launcher.join(timeout=30)
        except JoinTimeout as e:
            # best-effort teardown (runs in the experiment's finally path):
            # a straggler node must not destroy a fully computed result —
            # real worker errors still propagate above.
            import sys
            print(f"[distributed] warning: {e}", file=sys.stderr)
        # After join: the final parent push captures the services' end-of-run
        # state (replay tables and courier servers are parent-resident).
        # Worker processes pushed their own final snapshots on the way out.
        if self._telemetry_pusher is not None:
            self._telemetry_pusher.stop()

    def telemetry_snapshot(self):
        """Merged run-wide telemetry (None when telemetry is off).  Most
        informative AFTER ``stop()``, once every node's final push landed."""
        return (self.telemetry_hub.snapshot()
                if self.telemetry_hub is not None else None)


def make_distributed_agent(builder: AgentBuilder, env_factory,
                           num_actors: int,
                           seed: int = 0,
                           max_learner_steps: Optional[int] = None,
                           with_evaluator: bool = False,
                           num_replay_shards: Optional[int] = None,
                           prefetch_size: Optional[int] = None,
                           launcher: str = "local",
                           builder_factory=None,
                           spec=None,
                           num_envs_per_actor: Optional[int] = None,
                           inference: Optional[str] = None,
                           inference_max_batch_size: Optional[int] = None,
                           inference_max_wait_ms: float = 2.0,
                           num_learner_replicas: Optional[int] = None,
                           learner_average_period: Optional[int] = None,
                           telemetry: Optional[bool] = None,
                           telemetry_push_period_s: Optional[float] = None,
                           telemetry_jsonl: Optional[str] = None,
                           restart_policy=None,
                           chaos=None,
                           rpc_retry=None,
                           barrier_timeout_s: Optional[float] = None,
                           min_quorum: Optional[int] = None,
                           learner_sync: Optional[str] = None,
                           replay_routing: Optional[str] = None,
                           service_snapshot_period_s: Optional[float] = None,
                           restore=None) -> DistributedAgent:
    """Replicated actors + one learner + replay (+ background evaluator),
    on a Launchpad-lite graph — Fig 4 of the paper.

    ``launcher`` selects the execution backend from the registry
    (``"local"`` threads / ``"multiprocess"`` one OS process per worker).
    Backends that place workers out-of-process pickle the worker nodes; for
    those, pass the (module-level, picklable) ``builder_factory`` + ``spec``
    so each child rebuilds its own builder — the same factory
    ``ExperimentConfig`` already carries.

    With ``num_replay_shards > 1`` the replay service is a ``ShardedReplay``
    built from the builder's own ``make_replay`` — one replay *service* node
    per shard is placed in the program graph (each independently courier-
    addressable).  With ``prefetch_size > 0`` the learner consumes batches
    through a ``PrefetchingDataset`` instead of the synchronous dataset.

    ``num_envs_per_actor > 1`` makes every actor node a vectorized acting
    worker (a ``VectorEnv`` + batched actor, one policy dispatch per N env
    transitions); ``inference="server"`` additionally centralizes policy
    evaluation in a SEED-style ``InferenceServer`` service node that
    coalesces ``select_action`` RPCs from all actor workers into batched
    forward passes.  All four default to the builder's ``BuilderOptions``.

    ``num_learner_replicas > 1`` places one ``learner/replica_i`` node per
    replay shard (replica i consumes shard i's — optionally prefetching —
    dataset exclusively) plus a ``learner/param_server`` service that
    merges replica params/opt-state every ``learner_average_period``
    per-replica steps; the ``learner`` endpoint keeps serving
    ``get_variables`` unchanged, so actors, evaluators, and checkpoints
    see ONE logical learner.

    ``restart_policy`` (a ``repro.resilience.RestartPolicy``) makes the
    run elastic end to end: launchers with supervision support respawn
    dead ``role="worker"`` replicas under it, restore killed
    ``role="service"`` nodes from their periodic snapshots (re-bound at
    the same courier address; cadence ``service_snapshot_period_s``), and
    wrap every actor in graceful degradation so a service's restart
    window costs skipped adds, not dead workers.  ``chaos`` (a
    ``repro.resilience.ChaosPolicy``) resolves seeded fault schedules per
    actor replica AND per targeted service node.  ``rpc_retry`` (a
    ``repro.distributed.RetryConfig``) tunes courier reconnect/retry
    backoff in every worker.  ``barrier_timeout_s`` / ``min_quorum``
    enable the parameter server's quorum mode so averaging rounds
    tolerate stragglers and mid-restore replicas.  ``restore`` is a
    pre-launch hook called as ``restore(learner, table, counter)`` once
    every service exists but before any worker runs — exact-resume state
    is applied through it.

    ``learner_sync="async"`` drops the rendezvous entirely: a
    ``learner/param_service`` node (an ``AsyncParameterService``,
    recoverable like every service) replaces ``learner/param_server``,
    and each replica pushes its state / pulls the staleness-weighted
    blend at its own cadence — no replica ever waits for a straggler.
    Async engages the multi-learner machinery even at one replica (the
    parity configuration) and is incompatible with the quorum knobs.

    ``replay_routing="affinity"`` (with sharded replay and vectorized
    actors) hands every env its own ``ShardWriter`` onto the
    ``replay/shard_i`` node it is assigned to, bypassing the front-end
    routing cursor on the insert hot path while keeping global keys —
    and therefore priority updates and restores — interchangeable.
    """
    launcher_cls = get_launcher(launcher)
    program = Program("distributed_agent")
    program.restart_policy = restart_policy
    if service_snapshot_period_s is not None:
        if service_snapshot_period_s <= 0:
            raise ValueError(f"service_snapshot_period_s must be > 0, "
                             f"got {service_snapshot_period_s}")
        program.service_snapshot_period_s = service_snapshot_period_s
    if chaos is not None and launcher_cls.requires_pickling:
        # service kill schedules resolve launcher-side (the watchdog owns
        # the services); same process-isolation gate as actor chaos below
        program.chaos_policy = chaos
    options = builder.options
    # Telemetry first: every component constructed below registers its
    # metrics/probes against the (re)configured process registry.  The
    # parent process is node "services" — under the multiprocess launcher
    # all service nodes (replay, param server, inference, courier servers)
    # are parent-resident, so its registry carries their metrics; under the
    # local launcher it carries the whole run.
    telemetry_on = _resolve(telemetry, options.telemetry)
    push_period = _resolve(telemetry_push_period_s,
                           options.telemetry_push_period_s)
    _telemetry.configure(enabled=telemetry_on, node="services")
    metrics_hub = MetricsHub(jsonl_path=telemetry_jsonl) \
        if telemetry_on else None
    sync = _effective_sync(options, learner_sync)
    routing = _effective_routing(options, replay_routing)
    if sync == "async" and (barrier_timeout_s is not None
                            or min_quorum is not None):
        raise ValueError(
            "learner_sync='async' is incompatible with barrier_timeout_s/"
            "min_quorum: async replicas never rendezvous, so there is no "
            "round to time out")
    replicas, multi = _effective_replicas(options, num_learner_replicas)
    multi = multi or sync == "async"
    period = _resolve(learner_average_period,
                      options.learner_average_period)
    num_shards = (_replica_sharding(options, num_replay_shards, replicas)
                  if multi else _effective_shards(options, num_replay_shards))
    prefetch = _resolve(prefetch_size, options.prefetch_size)
    num_envs = _resolve(num_envs_per_actor, options.num_envs_per_actor)
    inference_mode = _resolve(inference, options.inference)
    if inference_mode not in ("local", "server"):
        raise ValueError(f"inference must be 'local' or 'server', "
                         f"got {inference_mode!r}")

    table = make_replay_shards(builder.make_replay, num_shards,
                               routing=routing)
    _register_replay_probe(table)
    datasets: List = []
    param_server = None
    async_service = None
    replica_workers: List[LearnerReplicaWorker] = []
    if multi:
        replica_learners, datasets, shards = _make_replica_learners(
            builder, table, replicas, prefetch=prefetch)
        if sync == "async":
            async_service = AsyncParameterService(replicas)
            replica_workers = [
                LearnerReplicaWorker(replica_learner, async_service, i,
                                     period, max_steps=max_learner_steps,
                                     dataset=datasets[i], shard=shards[i],
                                     sync_mode="async")
                for i, replica_learner in enumerate(replica_learners)]
            learner = MultiLearner(replica_learners, average_period=period,
                                   async_service=async_service,
                                   workers=replica_workers)
        else:
            param_server = ParameterServer(
                replicas, period, barrier_timeout_s=barrier_timeout_s,
                min_quorum=min_quorum)
            replica_workers = [
                LearnerReplicaWorker(replica_learner, param_server, i,
                                     period, max_steps=max_learner_steps,
                                     dataset=datasets[i], shard=shards[i])
                for i, replica_learner in enumerate(replica_learners)]
            learner = MultiLearner(replica_learners, average_period=period,
                                   param_server=param_server,
                                   workers=replica_workers)
        worker = None
    else:
        iterator = builder.make_dataset(table)
        if prefetch > 0:
            iterator = PrefetchingDataset.over_iterator(
                iterator, prefetch_size=prefetch)
            datasets = [iterator]
        learner = builder.make_learner(
            iterator, priority_update_cb=table.update_priorities)
        worker = _LearnerWorker(learner, max_steps=max_learner_steps)

    inference_server = None
    if inference_mode == "server":
        # window sized so one full sweep of the fleet fits in a single
        # forward pass (requests are rows: num_envs per vectorized actor);
        # max_batch_size=num_envs disables coalescing (one request per
        # pass — the per-actor-dispatch baseline fig15 compares against).
        max_batch = _resolve(inference_max_batch_size,
                             max(num_actors * num_envs, 2))
        if max_batch < num_envs:
            raise ValueError(
                f"inference_max_batch_size={max_batch} cannot hold one "
                f"vectorized actor's request of num_envs_per_actor="
                f"{num_envs} rows (requests are never split)")
        # Builders with stateful serving (KV caches, recurrent cores) bring
        # their own service; everyone else gets the generic batcher.
        inference_server = builder.make_inference_server(
            worker if worker is not None else learner,
            max_batch_size=max_batch,
            max_wait_ms=inference_max_wait_ms,
            update_period=options.variable_update_period,
            rng_seed=seed + 777_777)
        if inference_server is None:
            policy = builder.make_policy(evaluation=False)
            # Generic server inference supports exactly the builders that
            # use the DEFAULT feed-forward batched actor: an override means
            # the agent needs per-step state or per-env extras (recurrent
            # core state, IMPALA's behaviour logits, MCTS planning) that a
            # weightless InferenceClientActor cannot produce — reject at
            # config time rather than crash in the batcher thread mid-run.
            custom_batched = (type(builder).make_batched_actor
                              is not AgentBuilder.make_batched_actor)
            if policy is None or custom_batched \
                    or not policy_is_feed_forward(policy):
                raise ValueError(
                    f"{type(builder).__name__} does not support "
                    f"inference='server': the server batches plain "
                    f"(params, key, obs) -> action policies only (no "
                    f"recurrent state, no per-step extras) — keep "
                    f"inference='local' for this agent")
            inference_server = InferenceServer(
                policy, worker if worker is not None else learner,
                max_batch_size=max_batch,
                max_wait_ms=inference_max_wait_ms,
                update_period=options.variable_update_period,
                rng_seed=seed + 777_777)

    # What crosses into worker processes: a picklable builder stand-in when
    # the backend needs one, the shared builder instance otherwise.
    actor_builder = builder
    if launcher_cls.requires_pickling and builder_factory is not None:
        if spec is None:
            spec = getattr(builder, "spec", None)
        actor_builder = _DeferredBuilder(builder_factory, spec)

    counter_handle = program.add_node(
        "counter", Counter, role="service",
        interface=("increment", "get_counts"))
    # The hub is an ordinary service node: worker processes push snapshots
    # to it over the same courier plumbing as every other edge.  Added
    # before the worker nodes so launchers that pickle workers have a
    # courier server bound to it by then (Handle → RemoteHandle).
    hub_handle = None
    telemetry_pusher = None
    if metrics_hub is not None:
        hub_handle = program.add_node(
            "telemetry/hub", lambda: metrics_hub, role="service",
            interface=HUB_INTERFACE)
        telemetry_pusher = MetricsPusher(metrics_hub, "services",
                                         push_period).start()
    # replay placement: one service node per shard (independently
    # addressable — what a multi-host launcher would schedule onto separate
    # replay servers), plus the routing front-end the adders talk to.
    shard_handles = None
    if isinstance(table, ShardedReplay):
        shard_handles = [
            program.add_node(f"replay/shard_{i}", lambda s=shard: s,
                             role="service", interface=REPLAY_INTERFACE)
            for i, shard in enumerate(table.shards)]
    replay_handle = program.add_node("replay", lambda: table, role="service",
                                     interface=REPLAY_INTERFACE)
    if multi:
        # replica i has shard affinity with replay/shard_i; the param
        # server (or push/pull service) is the exchange point; the
        # "learner" endpoint stays the one variable source actors and
        # evaluators already use.
        if async_service is not None:
            program.add_node("learner/param_service",
                             lambda: async_service, role="service",
                             interface=ASYNC_PARAM_SERVICE_INTERFACE)
        else:
            program.add_node("learner/param_server", lambda: param_server,
                             role="service",
                             interface=PARAM_SERVER_INTERFACE)
        for i, replica_worker in enumerate(replica_workers):
            program.add_node(f"learner/replica_{i}",
                             lambda w=replica_worker: w, role="service",
                             interface=("get_variables",))
        learner_handle = program.add_node("learner", lambda: learner,
                                          role="service",
                                          interface=("get_variables",))
    else:
        learner_handle = program.add_node("learner", lambda: worker,
                                          role="service",
                                          interface=("get_variables",))
    inference_handle = None
    if inference_server is not None:
        inference_handle = program.add_node(
            "inference", lambda: inference_server, role="service",
            interface=getattr(inference_server, "INTERFACE",
                              INFERENCE_INTERFACE))
    actor_telemetry = None
    if hub_handle is not None:
        actor_telemetry = Replica(
            lambda i: WorkerTelemetry(hub_handle, f"actor/{i}", push_period))
    actor_chaos = None
    actor_rpc_chaos = None
    if chaos is not None and launcher_cls.requires_pickling:
        # Chaos needs process isolation: a kill schedule hard-exits the
        # worker's process (under the thread-backed local launcher that
        # would be the run itself), and RPC faults only exist over the
        # courier edges that out-of-process placement creates.
        actor_chaos = Replica(lambda i: chaos.schedule_for(f"actor/{i}"))
        actor_rpc_chaos = chaos
    actor_shard_tables = (shard_handles if routing == "affinity"
                          and shard_handles is not None else None)
    program.add_node(
        "actor", _ActorWorker, env_factory, actor_builder, learner_handle,
        counter_handle, replay_handle,
        Replica(lambda i: seed + 1000 * (i + 1)),
        role="worker", num_replicas=num_actors,
        num_envs=num_envs, inference=inference_handle,
        telemetry=actor_telemetry,
        chaos=actor_chaos, rpc_chaos=actor_rpc_chaos,
        rpc_retry=rpc_retry,
        resilient=restart_policy is not None,
        actor_index=Replica(lambda i: i),
        shard_tables=actor_shard_tables)
    eval_log_handle = None
    if with_evaluator:
        eval_log_handle = program.add_node(
            "eval_log", ReturnsLog, role="service",
            interface=("append", "items"))
        eval_telemetry = None
        if hub_handle is not None:
            eval_telemetry = WorkerTelemetry(hub_handle, "evaluator",
                                             push_period)
        program.add_node("evaluator", _EvaluatorWorker, env_factory,
                         actor_builder, learner_handle, counter_handle,
                         seed + 999_999, eval_log_handle, role="worker",
                         telemetry=eval_telemetry)

    if restore is not None:
        # Exact-resume: services (learner, replay, counter) exist but no
        # worker has produced a transition yet — restored state is the
        # first state anything observes.
        restore(learner, table, program.resolve("counter"))
    launched = launcher_cls(program).launch()
    agent = DistributedAgent(program, launched, learner, table,
                             program.resolve("counter"),
                             datasets=datasets,
                             eval_log=(program.resolve("eval_log")
                                       if with_evaluator else None),
                             inference_server=inference_server,
                             telemetry_hub=metrics_hub,
                             telemetry_pusher=telemetry_pusher)
    if with_evaluator and program.node("evaluator").placement != "process":
        agent.evaluator = program.resolve("evaluator")
    return agent
