"""Agent assembly: the same ``AgentBuilder`` yields the single-process agent
(§2.2) and the distributed program (§2.4) — Acme's central design claim.

Builders implement the typed ``repro.builders.AgentBuilder`` contract; the
execution schedule comes from their frozen ``BuilderOptions`` (no duck-typed
attribute probing).  These two assembly functions are the low-level layer;
``repro.experiments`` wraps them in the config-driven run API that examples,
benchmarks, and tests use.
"""
from __future__ import annotations

import itertools
import threading
from typing import Optional

from repro.builders import AgentBuilder
from repro.core import Agent, Counter, EnvironmentLoop, VariableClient
from repro.distributed.program import LocalLauncher, Program


def make_agent(builder: AgentBuilder, seed: int = 0) -> Agent:
    """Synchronous single-process agent: actor and learner in lockstep."""
    options = builder.options
    table = builder.make_replay()
    adder = builder.make_adder(table)
    iterator = builder.make_dataset(table)
    learner = builder.make_learner(
        iterator, priority_update_cb=table.update_priorities)
    client = VariableClient(learner,
                            update_period=options.variable_update_period)
    actor = builder.make_actor(builder.make_policy(evaluation=False),
                               client, adder, seed)
    consuming = table.selector.consumes

    def can_step():
        if table.rate_limiter.would_block_sample():
            return False
        return table.size() >= options.batch_size if consuming else True

    return Agent(actor, learner,
                 min_observations=options.min_observations,
                 observations_per_step=options.observations_per_step,
                 can_step=can_step)


class _LearnerWorker:
    """Learner node: run learner steps until stopped (rate limiter blocks us
    when we get ahead of the actors — §2.5)."""

    def __init__(self, learner, max_steps: Optional[int] = None):
        self.learner = learner
        self.max_steps = max_steps
        self._stop = threading.Event()

    def run(self):
        for i in itertools.count():
            if self._stop.is_set():
                return
            if self.max_steps is not None and i >= self.max_steps:
                return
            try:
                self.learner.step()
            except Exception:
                if self._stop.is_set():
                    return
                raise

    def stop(self):
        self._stop.set()

    def get_variables(self, names=()):
        return self.learner.get_variables(names)


class _ActorWorker:
    """Actor node: its own environment instance + loop (Fig 4)."""

    def __init__(self, env_factory, builder, variable_source, counter,
                 table, seed: int, max_episodes: Optional[int] = None):
        self.env = env_factory(seed)
        client = VariableClient(
            variable_source,
            update_period=builder.options.variable_update_period)
        adder = builder.make_adder(table)
        actor = builder.make_actor(builder.make_policy(evaluation=False),
                                   client, adder, seed)
        self.loop = EnvironmentLoop(self.env, actor, counter=counter,
                                    label="actor")
        self.max_episodes = max_episodes
        self._stop = threading.Event()

    def run(self):
        self.loop.run(num_episodes=self.max_episodes,
                      should_stop=self._stop.is_set)

    def stop(self):
        self._stop.set()


class DistributedAgent:
    """Handle onto a launched distributed program."""

    def __init__(self, program, launcher, learner, table, counter):
        self.program = program
        self.launcher = launcher
        self.learner = learner
        self.table = table
        self.counter = counter

    def stop(self):
        self.table.stop()
        self.launcher.stop()
        self.launcher.join(timeout=10)


class _EvaluatorWorker:
    """Background evaluator (§4.2): an actor with NO adder that periodically
    pulls weights and logs episode returns against learner steps."""

    def __init__(self, env_factory, builder, variable_source, counter,
                 seed: int, period_s: float = 1.0):
        self.env = env_factory(seed)
        client = VariableClient(variable_source, update_period=1)
        actor = builder.make_actor(builder.make_policy(evaluation=True),
                                   client, adder=None, seed=seed)
        self.loop = EnvironmentLoop(self.env, actor, counter=counter,
                                    label="evaluator", should_update=True)
        self.period_s = period_s
        self.returns = []
        self._stop = threading.Event()

    def run(self):
        while not self._stop.is_set():
            result = self.loop.run_episode()
            self.returns.append(result["episode_return"])
            self._stop.wait(self.period_s)

    def stop(self):
        self._stop.set()


def make_distributed_agent(builder: AgentBuilder, env_factory,
                           num_actors: int,
                           seed: int = 0,
                           max_learner_steps: Optional[int] = None,
                           with_evaluator: bool = False) -> DistributedAgent:
    """Replicated actors + one learner + replay (+ background evaluator),
    on a Launchpad-lite graph — Fig 4 of the paper."""
    program = Program("distributed_agent")
    counter = Counter()

    table = builder.make_replay()
    iterator = builder.make_dataset(table)
    learner = builder.make_learner(
        iterator, priority_update_cb=table.update_priorities)
    worker = _LearnerWorker(learner, max_steps=max_learner_steps)

    program.add_node("replay", lambda: table)
    learner_handle = program.add_node("learner", lambda: worker,
                                      is_worker=True)
    for i in range(num_actors):
        program.add_node(
            f"actor_{i}", _ActorWorker, env_factory, builder, learner_handle,
            counter, table, seed + 1000 * (i + 1), is_worker=True)
    if with_evaluator:
        program.add_node("evaluator", _EvaluatorWorker, env_factory, builder,
                         learner_handle, counter, seed + 999_999,
                         is_worker=True)

    launcher = LocalLauncher(program).launch()
    agent = DistributedAgent(program, launcher, learner, table, counter)
    if with_evaluator:
        agent.evaluator = program.resolve("evaluator")
    return agent
