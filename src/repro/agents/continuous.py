"""Continuous-control actor-critic agents (§3.4): DDPG, D4PG, MPO, DMPO.

All four share: n-step transition replay (uniform sampling — the paper found
prioritization gives minimal benefit here), Gaussian exploration noise,
target networks.  They differ in the policy loss (deterministic PG vs MPO's
EM) and the critic (expected vs C51 distributional).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.agents.common import JaxLearner, LearnerState, fresh_copy
from repro.builders import AgentBuilder, BuilderOptions
from repro.core.types import EnvironmentSpec
from repro.networks.heads import l2_project
from repro.networks.mlp import flatten_obs, mlp_apply, mlp_init
from repro.replay.dataset import ReplaySample


@dataclasses.dataclass
class ContinuousConfig:
    algo: str = "d4pg"            # ddpg | d4pg | mpo | dmpo
    hidden: int = 256
    policy_lr: float = 1e-3
    critic_lr: float = 1e-3
    discount: float = 0.99
    n_step: int = 5
    batch_size: int = 256
    min_replay_size: int = 1000
    max_replay_size: int = 1_000_000
    samples_per_insert: float = 32.0
    sigma: float = 0.2            # exploration noise
    target_update_period: int = 100
    # distributional critic
    num_atoms: int = 51
    vmin: float = 0.0
    vmax: float = 1000.0
    # mpo duals
    mpo_epsilon: float = 0.1
    mpo_eps_mean: float = 1e-2
    mpo_eps_std: float = 1e-5
    mpo_samples: int = 16


def _distributional(cfg):
    return cfg.algo in ("d4pg", "dmpo")


def _mpo_family(cfg):
    return cfg.algo in ("mpo", "dmpo")


def make_networks(spec: EnvironmentSpec, cfg: ContinuousConfig):
    obs_dim = int(np.prod(spec.observations.shape)) or 1
    act_dim = int(np.prod(spec.actions.shape)) or 1
    critic_out = cfg.num_atoms if _distributional(cfg) else 1
    policy_out = 2 * act_dim if _mpo_family(cfg) else act_dim

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "policy": mlp_init(k1, (obs_dim, cfg.hidden, cfg.hidden, policy_out)),
            "critic": mlp_init(k2, (obs_dim + act_dim, cfg.hidden, cfg.hidden,
                                    critic_out)),
            "log_temp": jnp.zeros(()),          # MPO duals
            "log_alpha_mean": jnp.zeros(()),
            "log_alpha_std": jnp.zeros(()),
        }

    def policy_dist(params, obs):
        out = mlp_apply(params["policy"], obs)
        if _mpo_family(cfg):
            mean, raw = jnp.split(out, 2, axis=-1)
            return jnp.tanh(mean), jax.nn.softplus(raw) + 1e-3
        return jnp.tanh(out), None

    def critic(params, obs, act):
        h = jnp.concatenate([obs, act], axis=-1)
        out = mlp_apply(params["critic"], h)
        if _distributional(cfg):
            return out                           # logits over atoms
        return out[..., 0]

    return init, policy_dist, critic, obs_dim, act_dim


def make_learner(spec: EnvironmentSpec, cfg: ContinuousConfig,
                 iterator: Iterator, rng_key) -> JaxLearner:
    init, policy_dist, critic, obs_dim, act_dim = make_networks(spec, cfg)
    popt = optim.adam(cfg.policy_lr, clip=40.0)
    copt = optim.adam(cfg.critic_lr, clip=40.0)
    params = init(rng_key)
    opt_state = (popt.init(params), copt.init(params))
    state = LearnerState(params, fresh_copy(params), opt_state,
                         jnp.zeros((), jnp.int32))
    atoms = jnp.linspace(cfg.vmin, cfg.vmax, cfg.num_atoms)

    def q_mean(params, obs, act):
        out = critic(params, obs, act)
        if _distributional(cfg):
            return jnp.sum(jax.nn.softmax(out, -1) * atoms, -1)
        return out

    def critic_loss(params, target_params, t, key):
        obs = flatten_obs(t.observation, spec.observations.shape)
        nobs = flatten_obs(t.next_observation, spec.observations.shape)
        act = t.action.reshape(obs.shape[0], -1)
        nmean, nstd = policy_dist(target_params, nobs)
        if nstd is not None:
            na = nmean + nstd * jax.random.normal(key, nmean.shape)
            na = jnp.clip(na, -1, 1)
        else:
            na = nmean
        if _distributional(cfg):
            target_logits = critic(target_params, nobs, na)
            target_p = jax.nn.softmax(target_logits, -1)
            z_target = t.reward[:, None] + t.discount[:, None] * atoms[None, :]
            proj = l2_project(z_target, target_p, atoms)
            logits = critic(params, obs, act)
            logp = jax.nn.log_softmax(logits, -1)
            loss = -jnp.mean(jnp.sum(jax.lax.stop_gradient(proj) * logp, -1))
        else:
            nq = critic(target_params, nobs, na)
            y = t.reward + t.discount * jax.lax.stop_gradient(nq)
            q = critic(params, obs, act)
            loss = 0.5 * jnp.mean(jnp.square(y - q))
        return loss

    def dpg_policy_loss(params, target_params, t):
        obs = flatten_obs(t.observation, spec.observations.shape)
        mean, _ = policy_dist(params, obs)
        q = q_mean(params, obs, mean)
        return -jnp.mean(q)

    def mpo_policy_loss(params, target_params, t, key):
        """Simplified MPO E/M steps with temperature + KL-alpha duals."""
        obs = flatten_obs(t.observation, spec.observations.shape)
        B = obs.shape[0]
        tmean, tstd = policy_dist(target_params, obs)
        k1, k2 = jax.random.split(key)
        samples = tmean[None] + tstd[None] * jax.random.normal(
            k1, (cfg.mpo_samples, B, act_dim))            # (S, B, A)
        samples = jnp.clip(samples, -1, 1)
        q = jax.vmap(lambda a: q_mean(target_params, obs, a))(samples)  # (S, B)
        temp = jnp.exp(params["log_temp"]) + 1e-8
        # E-step: weights + temperature dual loss
        logw = jax.nn.log_softmax(jax.lax.stop_gradient(q) / temp, axis=0)
        w = jnp.exp(logw)
        temp_loss = temp * (cfg.mpo_epsilon + jnp.mean(
            jax.nn.logsumexp(jax.lax.stop_gradient(q) / temp, axis=0)
            - jnp.log(cfg.mpo_samples)))
        # M-step: weighted max-likelihood under the online policy
        mean, std = policy_dist(params, obs)
        logp = -0.5 * jnp.sum(
            jnp.square((samples - mean[None]) / std[None])
            + 2 * jnp.log(std[None]), axis=-1)            # (S, B)
        ml_loss = -jnp.mean(jnp.sum(jax.lax.stop_gradient(w) * logp, axis=0))
        # decoupled KL regularization to the target policy
        kl_mean = jnp.mean(0.5 * jnp.sum(
            jnp.square((mean - tmean) / tstd), axis=-1))
        kl_std = jnp.mean(jnp.sum(
            jnp.log(std / tstd) + (jnp.square(tstd) /
                                   (2 * jnp.square(std))) - 0.5, axis=-1))
        a_mean = jnp.exp(params["log_alpha_mean"])
        a_std = jnp.exp(params["log_alpha_std"])
        alpha_mean_loss = a_mean * (cfg.mpo_eps_mean -
                                    jax.lax.stop_gradient(kl_mean))
        alpha_std_loss = a_std * (cfg.mpo_eps_std -
                                  jax.lax.stop_gradient(kl_std))
        policy_loss = ml_loss \
            + jax.lax.stop_gradient(a_mean) * kl_mean \
            + jax.lax.stop_gradient(a_std) * kl_std
        return policy_loss + temp_loss + alpha_mean_loss + alpha_std_loss

    def total_loss(params, target_params, t, key):
        k1, k2 = jax.random.split(key)
        cl = critic_loss(params, target_params, t, k1)
        if _mpo_family(cfg):
            pl = mpo_policy_loss(params, target_params, t, k2)
        else:
            pl = dpg_policy_loss(params, target_params, t)
        return cl + pl, {"critic_loss": cl, "policy_loss": pl}

    def update(state: LearnerState, sample: ReplaySample):
        t = sample.data
        key = jax.random.fold_in(jax.random.key(17), state.steps)
        grads, metrics = jax.grad(total_loss, has_aux=True)(
            state.params, state.target_params, t, key)
        p_opt, c_opt = state.opt_state
        pupd, p_opt = popt.update(grads, p_opt, state.params)
        params = optim.apply_updates(state.params, pupd)
        steps = state.steps + 1
        target = optim.periodic_update(params, state.target_params, steps,
                                       cfg.target_update_period)
        metrics["loss"] = metrics["critic_loss"] + metrics["policy_loss"]
        return (LearnerState(params, target, (p_opt, c_opt), steps),
                metrics, None)

    return JaxLearner(state, update, iterator)


def make_behavior_policy(spec: EnvironmentSpec, cfg: ContinuousConfig,
                         evaluation: bool = False):
    init, policy_dist, critic, obs_dim, act_dim = make_networks(spec, cfg)

    def policy(params, key, obs):
        obs = flatten_obs(obs, spec.observations.shape)
        mean, std = policy_dist(params, obs)
        a = mean[0]
        if not evaluation:
            noise = cfg.sigma if std is None else std[0]
            a = a + noise * jax.random.normal(key, a.shape)
        return jnp.clip(a, -1.0, 1.0)

    return policy


class ContinuousBuilder(AgentBuilder):
    def __init__(self, spec: EnvironmentSpec, cfg: ContinuousConfig = None,
                 seed: int = 0):
        cfg = cfg or ContinuousConfig()
        super().__init__(BuilderOptions(
            variable_update_period=10,
            min_observations=cfg.min_replay_size,
            observations_per_step=max(
                cfg.batch_size / cfg.samples_per_insert, 1.0)
            if cfg.samples_per_insert > 0 else 1.0,
            batch_size=cfg.batch_size))
        self.spec = spec
        self.cfg = cfg
        self.seed = seed

    def make_replay(self):
        from repro import replay as r
        cfg = self.cfg
        if cfg.samples_per_insert > 0:
            limiter = r.SampleToInsertRatio(
                cfg.samples_per_insert, cfg.min_replay_size,
                error_buffer=max(2 * cfg.samples_per_insert * cfg.batch_size, 1000))
        else:
            limiter = r.MinSize(cfg.min_replay_size)
        return r.Table("replay", cfg.max_replay_size, r.Uniform(self.seed),
                       limiter)

    def make_adder(self, table):
        from repro.adders import NStepTransitionAdder
        return NStepTransitionAdder(table, self.cfg.n_step, self.cfg.discount)

    def make_dataset(self, table):
        from repro.replay import as_iterator
        return as_iterator(table, self.cfg.batch_size)

    def make_learner(self, iterator, priority_update_cb=None):
        return make_learner(self.spec, self.cfg, iterator,
                            jax.random.key(self.seed))

    def make_policy(self, evaluation: bool = False):
        return make_behavior_policy(self.spec, self.cfg, evaluation)

    def make_actor(self, policy, variable_client, adder, seed: int = 0):
        from repro.core import FeedForwardActor
        return FeedForwardActor(policy, variable_client, adder, rng_seed=seed)


def builder_for(algo: str, spec: EnvironmentSpec, seed: int = 0,
                **overrides) -> ContinuousBuilder:
    cfg = ContinuousConfig(algo=algo, **overrides)
    return ContinuousBuilder(spec, cfg, seed)
