"""Shared learner scaffolding for all agents."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterator, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.interfaces import Learner


class LearnerState(NamedTuple):
    params: Any
    target_params: Any
    opt_state: Any
    steps: jax.Array
    extra: Any = ()


class JaxLearner(Learner):
    """Generic learner: pulls batches from an iterator, applies a jitted SGD
    step, publishes weights, accumulates learner walltime (§4.2 — persists
    through checkpoints)."""

    def __init__(self, state: LearnerState, update_fn, iterator: Iterator,
                 priority_update_cb: Optional[Callable] = None):
        self._state = state
        # NOTE: no donation here — actors snapshot params from another
        # thread (get_variables) and donation would delete buffers under
        # them.  The large-model train steps (repro.launch.steps) donate.
        self._update = jax.jit(update_fn)
        self._iterator = iterator
        self._priority_cb = priority_update_cb
        self._walltime = 0.0
        self._metrics: Dict[str, float] = {}

    @property
    def state(self) -> LearnerState:
        return self._state

    @state.setter
    def state(self, s: LearnerState):
        self._state = s

    @property
    def learner_walltime(self) -> float:
        return self._walltime

    def step(self) -> Dict[str, float]:
        sample = next(self._iterator)
        t0 = time.time()
        self._state, metrics, priorities = self._update(self._state, sample)
        jax.block_until_ready(priorities if priorities is not None
                              else metrics)
        self._walltime += time.time() - t0
        if self._priority_cb is not None and priorities is not None:
            self._priority_cb(np.asarray(sample.info.keys),
                              np.asarray(priorities))
        # ONE host transfer for all metrics + the step counter (a float(v)
        # per entry is a separate blocking device sync each).
        host_metrics, steps = jax.device_get((metrics, self._state.steps))
        self._metrics = {k: float(v) for k, v in host_metrics.items()}
        self._metrics["learner_steps"] = float(steps)
        self._metrics["learner_walltime"] = self._walltime
        return self._metrics

    def get_variables(self, names: Sequence[str] = ("policy",)):
        return [jax.tree.map(np.asarray, self._state.params)
                for _ in (names or ("policy",))]


def fresh_copy(tree):
    """Deep-copy a pytree's buffers (so params/target_params can both be
    donated without aliasing the same buffer twice)."""
    return jax.tree.map(jnp.copy, tree)


def importance_weights(probs: jax.Array, beta: float = 0.6) -> jax.Array:
    """PER importance-sampling weights, max-normalized (Schaul et al. 2015)."""
    w = (1.0 / jnp.maximum(probs.astype(jnp.float32), 1e-12)) ** beta
    return w / jnp.max(w)
