"""Acme baseline agents (§3): value-based, actor-critic, planning, offline.

Every agent exposes a typed ``repro.builders.AgentBuilder`` subclass;
importing this package registers all eight.
"""
from repro.agents import bc, builders, common, continuous, dqfd, dqn, impala, mcts, r2d2, r2d3  # noqa: F401
from repro.agents.builders import make_agent, make_distributed_agent  # noqa: F401
