"""IMPALA (§3.3): advantage actor-critic with V-trace off-policy correction.

Data flows through a FIFO queue (non-overlapping sequences, processed in
order) exactly as the paper describes; the V-trace recursion runs through the
Pallas kernel (interpret mode off-TPU) with the pure-jnp ref as fallback.
The behaviour logits are stored by the actor as extras so the learner can
form the importance ratios.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.agents.common import JaxLearner, LearnerState, fresh_copy
from repro.builders import AgentBuilder, BuilderOptions
from repro.core.actors import (STEP_MOD, BatchedFeedForwardActor,
                               _folded_policy)
from repro.core.types import EnvironmentSpec
from repro.kernels import ref as kernels_ref
from repro.networks.mlp import flatten_obs, mlp_apply, mlp_init
from repro.replay.dataset import ReplaySample


@dataclasses.dataclass
class IMPALAConfig:
    hidden: int = 64
    learning_rate: float = 6e-4
    discount: float = 0.99
    sequence_length: int = 20
    batch_size: int = 16
    entropy_cost: float = 0.01
    baseline_cost: float = 0.5
    max_queue_size: int = 1000
    clip_rho: float = 1.0
    clip_c: float = 1.0


def make_network(spec: EnvironmentSpec, cfg: IMPALAConfig):
    num_actions = spec.actions.num_values
    in_dim = int(np.prod(spec.observations.shape)) or 1

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "torso": mlp_init(k1, (in_dim, cfg.hidden, cfg.hidden)),
            "policy": mlp_init(k2, (cfg.hidden, num_actions)),
            "value": mlp_init(k3, (cfg.hidden, 1)),
        }

    def apply(params, obs):
        h = mlp_apply(params["torso"], obs, activate_final=True)
        return mlp_apply(params["policy"], h), mlp_apply(params["value"], h)[..., 0]

    return init, apply, in_dim, num_actions


def make_learner(spec: EnvironmentSpec, cfg: IMPALAConfig, iterator: Iterator,
                 rng_key) -> JaxLearner:
    init, apply, in_dim, num_actions = make_network(spec, cfg)
    opt = optim.adam(cfg.learning_rate, clip=40.0)
    params = init(rng_key)
    state = LearnerState(params, (), opt.init(params), jnp.zeros((), jnp.int32))

    def loss_fn(params, sample: ReplaySample):
        seq = sample.data                          # dict of (B, T, ...)
        obs = seq["observation"].astype(jnp.float32)
        B, T = obs.shape[:2]
        flat = obs.reshape(B * T, -1)
        logits, values = apply(params, flat)
        logits = logits.reshape(B, T, num_actions)
        values = values.reshape(B, T)
        actions = seq["action"].astype(jnp.int32)
        rewards = seq["reward"].astype(jnp.float32)
        discounts = seq["discount"].astype(jnp.float32) * cfg.discount
        mask = seq["mask"].astype(jnp.float32)
        behavior_logits = seq["behavior_logits"].astype(jnp.float32)

        # time-major, learner vs behaviour importance ratios
        def tm(x):
            return jnp.swapaxes(x, 0, 1)
        logp = jax.nn.log_softmax(logits)
        logp_a = jnp.take_along_axis(logp, actions[..., None], -1)[..., 0]
        blogp = jax.nn.log_softmax(behavior_logits)
        blogp_a = jnp.take_along_axis(blogp, actions[..., None], -1)[..., 0]
        rhos = jnp.exp(logp_a - blogp_a)

        # bootstrap: V(o_{t+1}) approximated by shifting values
        next_values = jnp.concatenate(
            [values[:, 1:], values[:, -1:]], axis=1)
        vs, pg_adv = kernels_ref.vtrace_ref(
            tm(values), tm(next_values), tm(rewards),
            tm(discounts), tm(jax.lax.stop_gradient(rhos)),
            clip_rho=cfg.clip_rho, clip_c=cfg.clip_c)
        vs, pg_adv = tm(vs), tm(pg_adv)

        m = mask
        pg_loss = -jnp.sum(logp_a * jax.lax.stop_gradient(pg_adv) * m) / jnp.sum(m)
        v_loss = 0.5 * jnp.sum(jnp.square(jax.lax.stop_gradient(vs) - values) * m) \
            / jnp.sum(m)
        probs = jax.nn.softmax(logits)
        entropy = -jnp.sum(jnp.sum(probs * logp, -1) * m) / jnp.sum(m)
        loss = pg_loss + cfg.baseline_cost * v_loss - cfg.entropy_cost * entropy
        return loss, {"loss": loss, "pg_loss": pg_loss, "v_loss": v_loss,
                      "entropy": entropy}

    def update(state: LearnerState, sample: ReplaySample):
        grads, metrics = jax.grad(loss_fn, has_aux=True)(state.params, sample)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = optim.apply_updates(state.params, updates)
        return (LearnerState(params, (), opt_state, state.steps + 1),
                metrics, None)

    return JaxLearner(state, update, iterator)


def make_behavior_policy(spec: EnvironmentSpec, cfg: IMPALAConfig):
    _, apply, _, num_actions = make_network(spec, cfg)

    def policy(params, key, obs):
        obs = flatten_obs(obs, spec.observations.shape)
        logits, _ = apply(params, obs)
        action = jax.random.categorical(key, logits[0])
        return action.astype(jnp.int32), logits[0]

    return policy


class IMPALAActor:
    """Feed-forward actor that also records behaviour logits as extras."""

    def __init__(self, policy, variable_client, adder, rng_seed=0):
        self._policy = jax.jit(_folded_policy(policy))
        self._client = variable_client
        self._adder = adder
        self._key = jax.random.key(rng_seed)
        self._steps = 0
        self._last_logits = None

    def select_action(self, observation):
        action, logits = self._policy(self._client.params, self._key,
                                      self._steps, jnp.asarray(observation))
        self._steps = (self._steps + 1) % STEP_MOD
        self._last_logits = np.asarray(logits)
        return np.asarray(action)

    def observe_first(self, timestep):
        if self._adder:
            self._adder.add_first(timestep)

    def observe(self, action, next_timestep):
        if self._adder:
            self._adder.add(action, next_timestep,
                            extras={"behavior_logits": self._last_logits})

    def update(self, wait=False):
        self._client.update(wait)


class BatchedIMPALAActor(BatchedFeedForwardActor):
    """Vectorized IMPALA acting: one vmapped dispatch returns N (action,
    logits) pairs; each env's behaviour logits ride into its own adder."""

    def __init__(self, policy, variable_client, adders, rng_seed=0):
        super().__init__(policy, variable_client, adders, rng_seed=rng_seed)
        self._last_logits = None

    def select_action(self, observation):
        actions, logits = self._run_policy(observation)
        self._last_logits = np.asarray(logits)
        return np.asarray(actions)

    def observe(self, action, next_timestep, env_id: int = 0):
        adder = self._adder(env_id)
        if adder:
            adder.add(action, next_timestep,
                      extras={"behavior_logits": self._last_logits[env_id]})


class IMPALABuilder(AgentBuilder):
    def __init__(self, spec: EnvironmentSpec, cfg: IMPALAConfig = None,
                 seed: int = 0):
        cfg = cfg or IMPALAConfig()
        # near on-policy: sync weights every step; step the learner as soon
        # as the queue holds a full batch (the Agent's can_step guard
        # prevents blocking on a short queue).
        super().__init__(BuilderOptions(
            variable_update_period=1,
            min_observations=cfg.sequence_length * cfg.batch_size,
            observations_per_step=1.0,
            batch_size=cfg.batch_size))
        self.spec = spec
        self.cfg = cfg
        self.seed = seed

    def make_replay(self):
        from repro import replay as r
        return r.Table("queue", self.cfg.max_queue_size, r.Fifo(),
                       r.MinSize(self.cfg.batch_size))

    def make_adder(self, table):
        from repro.adders.sequence import SequenceAdder
        return SequenceAdder(table, self.cfg.sequence_length,
                             period=self.cfg.sequence_length)

    def make_dataset(self, table):
        from repro.replay import as_iterator
        return as_iterator(table, self.cfg.batch_size)

    def make_learner(self, iterator, priority_update_cb=None):
        return make_learner(self.spec, self.cfg, iterator,
                            jax.random.key(self.seed))

    def make_policy(self, evaluation: bool = False):
        return make_behavior_policy(self.spec, self.cfg)

    def make_actor(self, policy, variable_client, adder, seed: int = 0):
        return IMPALAActor(policy, variable_client, adder, rng_seed=seed)

    def make_batched_actor(self, policy, variable_client, adders,
                           seed: int = 0):
        return BatchedIMPALAActor(policy, variable_client, adders,
                                  rng_seed=seed)
