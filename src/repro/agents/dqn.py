"""DQN (§3.2): double Q-learning, n-step targets (via the adder), dueling
heads, prioritized replay with importance weighting — the paper's enhanced
("in the spirit of Rainbow") implementation."""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.agents.common import JaxLearner, LearnerState, importance_weights
from repro.builders import AgentBuilder, BuilderOptions
from repro.core.types import EnvironmentSpec
from repro.networks import heads as heads_lib
from repro.networks.mlp import flatten_obs, mlp_apply, mlp_init
from repro.replay.dataset import ReplaySample


@dataclasses.dataclass
class DQNConfig:
    hidden: int = 64
    dueling: bool = True
    learning_rate: float = 1e-3
    discount: float = 0.99
    n_step: int = 3
    target_update_period: int = 100
    epsilon: float = 0.1
    batch_size: int = 64
    min_replay_size: int = 200
    max_replay_size: int = 100_000
    samples_per_insert: float = 4.0
    importance_beta: float = 0.6
    prioritized: bool = True


def make_q_network(spec: EnvironmentSpec, cfg: DQNConfig):
    num_actions = spec.actions.num_values
    in_dim = int(np.prod(spec.observations.shape)) or 1

    def init(key):
        k1, k2 = jax.random.split(key)
        p = {"torso": mlp_init(k1, (in_dim, cfg.hidden, cfg.hidden))}
        if cfg.dueling:
            p["head"] = heads_lib.dueling_init(k2, cfg.hidden, cfg.hidden,
                                               num_actions)
        else:
            p["head"] = {"q": mlp_init(k2, (cfg.hidden, num_actions))}
        return p

    def apply(params, obs):
        h = mlp_apply(params["torso"], obs, activate_final=True)
        if cfg.dueling:
            return heads_lib.dueling_apply(params["head"], h)
        return mlp_apply(params["head"]["q"], h)

    return init, apply, in_dim, num_actions


def make_learner(spec: EnvironmentSpec, cfg: DQNConfig, iterator: Iterator,
                 rng_key, priority_update_cb=None) -> JaxLearner:
    init, apply, in_dim, num_actions = make_q_network(spec, cfg)
    opt = optim.adam(cfg.learning_rate, clip=40.0)
    params = init(rng_key)
    from repro.agents.common import fresh_copy
    state = LearnerState(params, fresh_copy(params), opt.init(params),
                         jnp.zeros((), jnp.int32))

    def loss_fn(params, target_params, sample: ReplaySample):
        t = sample.data
        obs = flatten_obs(t.observation, spec.observations.shape)
        next_obs = flatten_obs(t.next_observation, spec.observations.shape)
        q = apply(params, obs)
        q_next_online = apply(params, next_obs)
        q_next_target = apply(target_params, next_obs)
        a_star = jnp.argmax(q_next_online, axis=-1)
        next_v = jnp.take_along_axis(q_next_target, a_star[:, None], -1)[:, 0]
        y = t.reward + t.discount * jax.lax.stop_gradient(next_v)
        q_taken = jnp.take_along_axis(q, t.action[:, None].astype(jnp.int32),
                                      -1)[:, 0]
        td = y - q_taken
        if cfg.prioritized:
            w = importance_weights(jnp.asarray(sample.info.probabilities),
                                   cfg.importance_beta)
        else:
            w = jnp.ones_like(td)
        loss = 0.5 * jnp.mean(w * jnp.square(td))
        return loss, td

    def update(state: LearnerState, sample: ReplaySample):
        (loss, td), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, state.target_params, sample)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = optim.apply_updates(state.params, updates)
        steps = state.steps + 1
        target = optim.periodic_update(params, state.target_params, steps,
                                       cfg.target_update_period)
        new_state = LearnerState(params, target, opt_state, steps)
        priorities = jnp.abs(td)
        return new_state, {"loss": loss}, priorities

    return JaxLearner(state, update, iterator,
                      priority_update_cb=priority_update_cb if cfg.prioritized
                      else None)


def make_behavior_policy(spec: EnvironmentSpec, cfg: DQNConfig,
                         epsilon: Optional[float] = None):
    _, apply, _, num_actions = make_q_network(spec, cfg)
    eps = cfg.epsilon if epsilon is None else epsilon

    def policy(params, key, obs):
        obs = flatten_obs(obs, spec.observations.shape)
        q = apply(params, obs)[0]
        greedy = jnp.argmax(q)
        rand = jax.random.randint(key, (), 0, num_actions)
        explore = jax.random.uniform(key) < eps
        return jnp.where(explore, rand, greedy).astype(jnp.int32)

    return policy


def make_eval_policy(spec: EnvironmentSpec, cfg: DQNConfig):
    return make_behavior_policy(spec, cfg, epsilon=0.0)


class DQNBuilder(AgentBuilder):
    """Typed builder (repro.builders.AgentBuilder) for DQN."""

    def __init__(self, spec: EnvironmentSpec, cfg: DQNConfig = None,
                 seed: int = 0, spi_tolerance: float = None):
        from repro import replay as replay_lib
        cfg = cfg or DQNConfig()
        super().__init__(BuilderOptions(
            variable_update_period=10,
            min_observations=cfg.min_replay_size,
            observations_per_step=max(
                cfg.batch_size / cfg.samples_per_insert, 1.0)
            if cfg.samples_per_insert > 0 else 1.0,
            batch_size=cfg.batch_size))
        self.spec = spec
        self.cfg = cfg
        self.seed = seed
        self._replay_lib = replay_lib
        self.spi_tolerance = spi_tolerance

    def make_replay(self):
        r = self._replay_lib
        cfg = self.cfg
        tol = self.spi_tolerance
        if cfg.samples_per_insert > 0:
            limiter = r.SampleToInsertRatio(
                cfg.samples_per_insert, cfg.min_replay_size,
                error_buffer=tol if tol is not None
                else max(cfg.samples_per_insert * 2 * cfg.batch_size, 100.0))
        else:
            limiter = r.MinSize(cfg.min_replay_size)
        selector = r.Prioritized() if cfg.prioritized else r.Uniform(self.seed)
        return r.Table("replay", cfg.max_replay_size, selector, limiter)

    def make_adder(self, table):
        from repro.adders import NStepTransitionAdder
        return NStepTransitionAdder(table, self.cfg.n_step, self.cfg.discount,
                                    priority=100.0)

    def make_dataset(self, table):
        from repro.replay import as_iterator
        return as_iterator(table, self.cfg.batch_size)

    def make_learner(self, iterator, priority_update_cb=None):
        import jax
        return make_learner(self.spec, self.cfg, iterator,
                            jax.random.key(self.seed),
                            priority_update_cb=priority_update_cb)

    def make_policy(self, evaluation: bool = False):
        if evaluation:
            return make_eval_policy(self.spec, self.cfg)
        return make_behavior_policy(self.spec, self.cfg)

    def make_actor(self, policy, variable_client, adder, seed: int = 0):
        from repro.core import FeedForwardActor
        return FeedForwardActor(policy, variable_client, adder, rng_seed=seed)
