"""R2D3 (§3.6): R2D2 + expert demonstrations.

The recurrent learner's batches interleave agent-replay sequences with a
fixed table of demonstration sequences at a configurable ratio (Gulcehre et
al., 2020 — 'Making efficient use of demonstrations').
"""
from __future__ import annotations

import dataclasses

from repro.agents import r2d2 as r2d2_lib
from repro.agents.dqfd import mixed_iterator
from repro.core.types import EnvironmentSpec


@dataclasses.dataclass
class R2D3Config(r2d2_lib.R2D2Config):
    demo_ratio: float = 0.25


class R2D3Builder(r2d2_lib.R2D2Builder):
    """R2D2 builder whose dataset mixes in demonstration sequences.

    Inherits the ``AgentBuilder`` contract (and its ``BuilderOptions``)
    from ``R2D2Builder``; only the dataset and the priority-update filter
    differ.
    """

    def __init__(self, spec: EnvironmentSpec, demo_sequences,
                 cfg: R2D3Config = None, seed: int = 0):
        super().__init__(spec, cfg or R2D3Config(), seed)
        self.demos = demo_sequences

    def make_demo_table(self):
        from repro import replay as r
        table = r.Table("demo_seqs", max(len(self.demos), 1), r.Prioritized(),
                        r.MinSize(1))
        for item in self.demos:
            table.insert(item, priority=1.0)
        return table

    def make_dataset(self, table):
        demo_table = self.make_demo_table()
        return mixed_iterator(table, demo_table, self.cfg.batch_size,
                              self.cfg.demo_ratio)

    def make_learner(self, iterator, priority_update_cb=None):
        import jax
        inner_cb = priority_update_cb

        def cb(keys, priorities):
            if inner_cb is None:
                return
            m = keys >= 0
            inner_cb(keys[m], priorities[m])

        return r2d2_lib.make_learner(self.spec, self.cfg, iterator,
                                     jax.random.key(self.seed),
                                     priority_update_cb=cb)
