"""DQfD / R2D3 (§3.6): RL with Expert Demonstrations.

Learner batches are a fixed-ratio interleave of agent replay and an expert
demonstration table (both prioritized), applied to the DQN learner (DQfD) or
the R2D2 learner (R2D3).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List

import numpy as np

from repro.agents import dqn as dqn_lib
from repro.core.types import EnvironmentSpec, Transition
from repro.replay.dataset import ReplaySample, SampleInfo, as_iterator
from repro.replay.table import Table


@dataclasses.dataclass
class DQfDConfig(dqn_lib.DQNConfig):
    demo_ratio: float = 0.25           # fraction of each batch from demos


def mixed_iterator(agent_table: Table, demo_table: Table, batch_size: int,
                   demo_ratio: float) -> Iterator[ReplaySample]:
    """Interleave samples: ceil(ratio*B) demo items + rest agent items."""
    import jax
    n_demo = max(int(round(demo_ratio * batch_size)), 1)
    n_agent = batch_size - n_demo
    while True:
        demo = demo_table.sample(n_demo)
        agent = agent_table.sample(n_agent)
        items = [it.data for it, _ in demo] + [it.data for it, _ in agent]
        keys = np.array([it.key for it, _ in demo] +
                        [it.key for it, _ in agent], np.int64)
        probs = np.array([p for _, p in demo] + [p for _, p in agent])
        data = jax.tree.map(lambda *xs: np.stack(xs, 0), *items)
        # priorities are only updated on the agent table; mark demo keys -1
        keys[:n_demo] = -1
        yield ReplaySample(SampleInfo(keys, probs), data)


def generate_deep_sea_demos(env, num_demos: int, success_rate: float = 1.0,
                            n_step: int = 1, discount: float = 1.0,
                            seed: int = 0) -> List[Transition]:
    """Optimal-policy demonstrations for DeepSea (§4.8: 'generated using the
    optimal policy, which has knowledge of the action mapping')."""
    from repro.adders.transition import NStepTransitionAdder
    from repro.replay import MinSize, Table, Uniform

    tmp = Table("demos_tmp", 1_000_000, Uniform(seed), MinSize(1))
    adder = NStepTransitionAdder(tmp, n_step, discount)
    rng = np.random.RandomState(seed)
    for ep in range(num_demos):
        succeed = rng.rand() < success_rate
        ts = env.reset()
        adder.add_first(ts)
        while not ts.last():
            a = env.optimal_action() if succeed else int(rng.randint(2))
            ts = env.step(a)
            adder.add(a, ts)
    items = [tmp._items[k].data for k in tmp._order]
    return items


def generate_sequence_demos(env, optimal_action_fn, num_demos: int,
                            sequence_length: int, period: int,
                            seed: int = 0):
    """Demonstration sequences for R2D3 (recurrent learners)."""
    from repro.adders.sequence import SequenceAdder
    from repro.replay import MinSize, Table, Uniform

    tmp = Table("demo_seqs", 1_000_000, Uniform(seed), MinSize(1))
    adder = SequenceAdder(tmp, sequence_length, period)
    for _ in range(num_demos):
        ts = env.reset()
        adder.add_first(ts)
        while not ts.last():
            a = optimal_action_fn(env)
            ts = env.step(a)
            adder.add(a, ts)
    return [tmp._items[k].data for k in tmp._order]


class DQfDBuilder(dqn_lib.DQNBuilder):
    """DQN builder whose dataset mixes in a demonstration table.

    Inherits the ``AgentBuilder`` contract (and its ``BuilderOptions``,
    computed from the config) from ``DQNBuilder``; only the dataset and the
    priority-update filter differ.
    """

    def __init__(self, spec: EnvironmentSpec, demos, cfg: DQfDConfig = None,
                 seed: int = 0):
        super().__init__(spec, cfg or DQfDConfig(), seed)
        self.demos = demos

    def make_demo_table(self):
        from repro import replay as r
        table = r.Table("demos", max(len(self.demos), 1), r.Prioritized(),
                        r.MinSize(1))
        for item in self.demos:
            table.insert(item, priority=1.0)
        return table

    def make_dataset(self, table):
        demo_table = self.make_demo_table()
        return mixed_iterator(table, demo_table, self.cfg.batch_size,
                              self.cfg.demo_ratio)

    def make_learner(self, iterator, priority_update_cb=None):
        # filter demo keys (-1) out of priority updates
        inner_cb = priority_update_cb

        def cb(keys, priorities):
            if inner_cb is None:
                return
            m = keys >= 0
            inner_cb(keys[m], priorities[m])

        import jax
        return dqn_lib.make_learner(self.spec, self.cfg, iterator,
                                    jax.random.key(self.seed),
                                    priority_update_cb=cb)
