"""MCTS agent (§3.5): AlphaZero-lite — planning with a (perfect) simulator,
search guided by policy/value networks, UCT selection (Eq. 19), policy
trained by KL to the visit-count distribution (Eq. 20), value by TD.
"""
from __future__ import annotations

import copy
import dataclasses
import math
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.agents.common import JaxLearner, LearnerState
from repro.builders import AgentBuilder, BuilderOptions
from repro.core.types import EnvironmentSpec
from repro.networks.mlp import flatten_obs, mlp_apply, mlp_init
from repro.replay.dataset import ReplaySample


@dataclasses.dataclass
class MCTSConfig:
    hidden: int = 64
    learning_rate: float = 1e-3
    discount: float = 0.99
    num_simulations: int = 32
    uct_c: float = 1.25
    search_depth: int = 16
    batch_size: int = 32
    min_replay_size: int = 100
    max_replay_size: int = 50_000
    temperature: float = 1.0


def make_network(spec: EnvironmentSpec, cfg: MCTSConfig):
    num_actions = spec.actions.num_values
    in_dim = int(np.prod(spec.observations.shape)) or 1

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "torso": mlp_init(k1, (in_dim, cfg.hidden, cfg.hidden)),
            "policy": mlp_init(k2, (cfg.hidden, num_actions)),
            "value": mlp_init(k3, (cfg.hidden, 1)),
        }

    def apply(params, obs):
        h = mlp_apply(params["torso"], obs, activate_final=True)
        return mlp_apply(params["policy"], h), mlp_apply(params["value"], h)[..., 0]

    return init, apply, in_dim, num_actions


class _Node:
    __slots__ = ("prior", "value_sum", "visits", "children", "reward",
                 "terminal")

    def __init__(self, prior: float):
        self.prior = prior
        self.value_sum = 0.0
        self.visits = 0
        self.children = {}
        self.reward = 0.0
        self.terminal = False

    @property
    def value(self):
        return self.value_sum / self.visits if self.visits else 0.0


class MCTSActor:
    """Actor that plans with a copyable simulator (env must support
    deepcopy — all our envs do)."""

    def __init__(self, spec, cfg: MCTSConfig, variable_client, adder=None,
                 model_env=None, seed: int = 0):
        self.spec = spec
        self.cfg = cfg
        self._client = variable_client
        self._adder = adder
        _, apply, _, self.num_actions = make_network(spec, cfg)
        self._apply = jax.jit(apply)
        self._rng = np.random.RandomState(seed)
        self._model_env = model_env
        self._last_probs = None

    def _evaluate(self, obs):
        logits, value = self._apply(self._client.params,
                                    flatten_obs(obs, self.spec.observations.shape))
        return np.asarray(jax.nn.softmax(logits[0])), float(value[0])

    def _search(self, env, root_obs) -> np.ndarray:
        priors, _ = self._evaluate(root_obs)
        root = _Node(1.0)
        for a in range(self.num_actions):
            root.children[a] = _Node(float(priors[a]))

        for _ in range(self.cfg.num_simulations):
            sim = copy.deepcopy(env)
            node = root
            path = [node]
            depth = 0
            value = 0.0
            # selection + expansion
            while depth < self.cfg.search_depth:
                best_a, best_score = None, -1e9
                sqrt_n = math.sqrt(max(node.visits, 1))
                for a, child in node.children.items():
                    u = self.cfg.uct_c * sqrt_n / (child.visits + 1) * child.prior
                    score = child.value + u
                    if score > best_score:
                        best_a, best_score = a, score
                child = node.children[best_a]
                ts = sim.step(best_a)
                child.reward = float(ts.reward or 0.0)
                depth += 1
                path.append(child)
                node = child
                if ts.last():
                    child.terminal = True
                    value = 0.0
                    break
                if not child.children:
                    priors, value = self._evaluate(ts.observation)
                    for a in range(self.num_actions):
                        child.children[a] = _Node(float(priors[a]))
                    break
            # backup
            g = value
            for n in reversed(path[1:]):
                g = n.reward + self.cfg.discount * g
                n.value_sum += g
                n.visits += 1
            root.visits += 1

        visits = np.array([root.children[a].visits
                           for a in range(self.num_actions)], np.float64)
        if visits.sum() == 0:
            visits += 1
        probs = visits ** (1.0 / self.cfg.temperature)
        return probs / probs.sum()

    def select_action(self, observation):
        env = self._model_env
        probs = self._search(env, observation)
        self._last_probs = probs.astype(np.float32)
        return np.int32(self._rng.choice(self.num_actions, p=probs))

    def observe_first(self, timestep):
        if self._adder:
            self._adder.add_first(timestep)

    def observe(self, action, next_timestep):
        if self._adder:
            self._adder.add(action, next_timestep,
                            extras={"search_probs": self._last_probs})

    def update(self, wait=False):
        self._client.update(wait)


def make_learner(spec: EnvironmentSpec, cfg: MCTSConfig, iterator: Iterator,
                 rng_key) -> JaxLearner:
    init, apply, in_dim, num_actions = make_network(spec, cfg)
    opt = optim.adam(cfg.learning_rate)
    params = init(rng_key)
    state = LearnerState(params, (), opt.init(params), jnp.zeros((), jnp.int32))

    def loss_fn(params, seq):
        obs = seq["observation"].astype(jnp.float32)
        B, T = obs.shape[:2]
        logits, values = apply(params, obs.reshape(B * T, -1))
        logits = logits.reshape(B, T, -1)
        values = values.reshape(B, T)
        probs = seq["search_probs"].astype(jnp.float32)
        mask = seq["mask"].astype(jnp.float32)
        # policy: KL(pi_mcts || pi_theta) (Eq. 20)
        logp = jax.nn.log_softmax(logits)
        pi_loss = -jnp.sum(probs * logp, -1)
        # value: TD(0) to observed returns
        rewards = seq["reward"].astype(jnp.float32)
        disc = seq["discount"].astype(jnp.float32) * cfg.discount
        v_next = jnp.concatenate([values[:, 1:], values[:, -1:]], 1)
        td = rewards + disc * jax.lax.stop_gradient(v_next) - values
        v_loss = 0.5 * jnp.square(td)
        loss = jnp.sum((pi_loss + v_loss) * mask) / jnp.maximum(jnp.sum(mask), 1)
        return loss, {"loss": loss}

    def update(state: LearnerState, sample: ReplaySample):
        grads, metrics = jax.grad(loss_fn, has_aux=True)(state.params,
                                                         sample.data)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = optim.apply_updates(state.params, updates)
        return (LearnerState(params, (), opt_state, state.steps + 1),
                metrics, None)

    return JaxLearner(state, update, iterator)


class MCTSBuilder(AgentBuilder):
    def __init__(self, spec: EnvironmentSpec, model_env_factory,
                 cfg: MCTSConfig = None, seed: int = 0):
        cfg = cfg or MCTSConfig()
        super().__init__(BuilderOptions(
            variable_update_period=5,
            min_observations=cfg.min_replay_size,
            observations_per_step=4.0,
            batch_size=cfg.batch_size))
        self.spec = spec
        self.cfg = cfg
        self.seed = seed
        self.model_env_factory = model_env_factory

    def make_replay(self):
        from repro import replay as r
        return r.Table("replay", self.cfg.max_replay_size, r.Uniform(self.seed),
                       r.MinSize(self.cfg.min_replay_size))

    def make_adder(self, table):
        from repro.adders.sequence import SequenceAdder
        return SequenceAdder(table, 10, period=10)

    def make_dataset(self, table):
        from repro.replay import as_iterator
        return as_iterator(table, self.cfg.batch_size)

    def make_learner(self, iterator, priority_update_cb=None):
        return make_learner(self.spec, self.cfg, iterator,
                            jax.random.key(self.seed))

    def make_policy(self, evaluation: bool = False):
        return None   # MCTS plans; no standalone policy fn

    def make_actor(self, policy, variable_client, adder, seed: int = 0):
        return MCTSActor(self.spec, self.cfg, variable_client, adder,
                         model_env=self.model_env_factory(seed), seed=seed)

    def make_batched_actor(self, policy, variable_client, adders,
                           seed: int = 0):
        raise NotImplementedError(
            "MCTS actors plan with a per-environment simulator; vectorized "
            "acting (num_envs_per_actor > 1) is not supported")
