"""Unified model zoo: dense/GQA, MoE, Mamba2-SSM, Zamba2-hybrid, VLM, Whisper.

All models are pure functions over a param pytree.  Per-layer parameters are
*stacked* along a leading ``layers`` axis and executed with ``jax.lax.scan``
so that 30-48 layer models lower to compact HLO (critical for the 80-combo
dry-run sweep) and per-layer remat is a single ``jax.checkpoint``.

Public API:
  init(rng, cfg, dtype)                      -> params
  forward(params, cfg, batch, remat=...)     -> (logits, aux_losses)
  init_cache(cfg, batch, max_len, dtype)     -> decode cache
  decode_step(params, cfg, cache, token, pos)-> (logits, new_cache)
  prefill(params, cfg, cache, tokens)        -> (last logits, new_cache)

The ``*_embedded`` variants (``forward_embedded``, ``prefill_embedded``,
``decode_step_embedded``) run the dense stack over caller-supplied
embeddings instead of token ids and return features instead of logits — the
entry points for non-LM heads like ``repro.policies`` (observation
embeddings in, Q-values out).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers, moe as moe_lib, ssm as ssm_lib
from repro.models.config import ArchConfig
from repro.sharding import shard

Params = Dict[str, Any]


def _stack_init(key, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(remat)


# ======================================================================
# Init
# ======================================================================
def _dense_block_init(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": layers.rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "ln2": layers.rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.arch_type == "moe":
        p["moe"] = moe_lib.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = layers.mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _ssm_block_init(key, cfg: ArchConfig, dtype):
    return {
        "ln": layers.rmsnorm_init(cfg.d_model, dtype),
        "ssm": ssm_lib.ssm_init(key, cfg, dtype),
    }


def _xattn_block_init(key, cfg: ArchConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "lnx": layers.rmsnorm_init(cfg.d_model, dtype),
        "xattn": attn.attn_init(k2, cfg, dtype),
        "ln2": layers.rmsnorm_init(cfg.d_model, dtype),
        "mlp": layers.mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init(rng, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ke, kb, kh, kx = jax.random.split(rng, 4)
    pv = cfg.padded_vocab_size
    params: Params = {
        "embed": layers.embed_init(ke, pv, cfg.d_model, dtype),
        "final_norm": layers.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.embed_init(kh, pv, cfg.d_model, dtype)

    if cfg.arch_type in ("dense", "moe", "vlm"):
        params["blocks"] = _stack_init(
            kb, cfg.num_layers, lambda k: _dense_block_init(k, cfg, dtype))
    elif cfg.arch_type == "ssm":
        params["blocks"] = _stack_init(
            kb, cfg.num_layers, lambda k: _ssm_block_init(k, cfg, dtype))
    elif cfg.arch_type == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.num_layers, every)
        kg, kr, ka = jax.random.split(kb, 3)
        params["blocks"] = _stack_init(
            kg, n_groups * every,
            lambda k: _ssm_block_init(k, cfg, dtype))
        # reshape leading axis to (groups, every)
        params["blocks"] = jax.tree.map(
            lambda x: x.reshape(n_groups, every, *x.shape[1:]), params["blocks"])
        if rem:
            params["tail_blocks"] = _stack_init(
                kr, rem, lambda k: _ssm_block_init(k, cfg, dtype))
        params["shared_attn"] = _dense_block_init(ka, cfg, dtype)  # one weight set
    elif cfg.arch_type == "audio":
        params["enc_blocks"] = _stack_init(
            kx, cfg.encoder_layers, lambda k: _dense_block_init(k, cfg, dtype))
        params["enc_norm"] = layers.rmsnorm_init(cfg.d_model, dtype)
        params["blocks"] = _stack_init(
            kb, cfg.num_layers, lambda k: _xattn_block_init(k, cfg, dtype))
    else:
        raise ValueError(cfg.arch_type)
    return params


# ======================================================================
# Forward (train / prefill)
# ======================================================================
def _dense_block(bp, cfg: ArchConfig, x, positions, aux, *, causal=True, enc=None):
    h = attn.attention(bp["attn"], cfg, layers.rmsnorm(bp["ln1"], x, cfg.rmsnorm_eps),
                       positions, causal=causal)
    x = x + h
    if enc is not None:  # whisper decoder cross-attention
        h = attn.attention(bp["xattn"], cfg,
                           layers.rmsnorm(bp["lnx"], x, cfg.rmsnorm_eps),
                           positions, causal=False, kv=enc)
        x = x + h
    y = layers.rmsnorm(bp["ln2"], x, cfg.rmsnorm_eps)
    if cfg.arch_type == "moe":
        f, losses = moe_lib.moe_ffn(bp["moe"], cfg, y)
        aux = {k: aux.get(k, 0.0) + v for k, v in losses.items()}
    else:
        f = layers.mlp(bp["mlp"], y)
    return x + f, aux


def _ssm_block(bp, cfg: ArchConfig, x):
    h, _ = ssm_lib.ssm_forward(bp["ssm"], cfg,
                               layers.rmsnorm(bp["ln"], x, cfg.rmsnorm_eps))
    return x + h


def _run_dense_stack(blocks, cfg, x, positions, remat, causal=True, enc=None):
    aux0 = {"moe_aux": jnp.float32(0), "moe_z": jnp.float32(0)} \
        if cfg.arch_type == "moe" else {}

    def body(carry, bp):
        x, aux = carry
        x, aux = _dense_block(bp, cfg, x, positions, aux, causal=causal, enc=enc)
        # sequence-parallel residual stream between blocks (Megatron SP):
        # the remat-scan carry is then 1/model_parallel the size.
        x = shard(x, "batch", "act_seq", "d_model")
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(_maybe_remat(body, remat), (x, aux0), blocks)
    return x, aux


def forward_features(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
                     *, remat: str = "full"):
    """batch: {"tokens": (b, s)} (+ "vision": (b, V, d) | "frames": (b, F, d)).

    Returns (final hidden states at text positions, aux loss dict).
    """
    tokens = batch["tokens"]
    x = layers.embed(params["embed"], tokens)
    if cfg.arch_type == "vlm":
        vision = batch["vision"].astype(x.dtype)       # projected patch embeds
        x = jnp.concatenate([vision, x], axis=1)
    x = shard(x, "batch", "seq", "d_model")
    seq = x.shape[1]
    positions = jnp.arange(seq)
    aux: Dict[str, jax.Array] = {}

    if cfg.arch_type in ("dense", "moe", "vlm"):
        x, aux = _run_dense_stack(params["blocks"], cfg, x, positions, remat)

    elif cfg.arch_type == "ssm":
        def body(x, bp):
            return shard(_ssm_block(bp, cfg, x), "batch", "act_seq", "d_model"), None
        x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["blocks"])

    elif cfg.arch_type == "hybrid":
        shared = params["shared_attn"]

        def group_body(x, gp):
            def inner(x, bp):
                return shard(_ssm_block(bp, cfg, x), "batch", "act_seq", "d_model"), None
            x, _ = jax.lax.scan(inner, x, gp)
            x, _ = _dense_block(shared, cfg, x, positions, {})
            x = shard(x, "batch", "act_seq", "d_model")
            return x, None

        x, _ = jax.lax.scan(_maybe_remat(group_body, remat), x, params["blocks"])
        if "tail_blocks" in params:
            def body(x, bp):
                return _ssm_block(bp, cfg, x), None
            x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["tail_blocks"])

    elif cfg.arch_type == "audio":
        frames = batch["frames"].astype(x.dtype)
        enc = frames + layers.sinusoid_positions(frames.shape[1], cfg.d_model
                                                 ).astype(x.dtype)[None]
        enc_pos = jnp.arange(enc.shape[1])
        enc, _ = _run_dense_stack(params["enc_blocks"], cfg, enc, enc_pos,
                                  remat, causal=False)
        enc = layers.rmsnorm(params["enc_norm"], enc, cfg.rmsnorm_eps)
        x = x + layers.sinusoid_positions(seq, cfg.d_model).astype(x.dtype)[None]

        def body(carry, bp):
            x, aux = carry
            # per-layer cross K/V from encoder output
            k = jnp.einsum("bsd,dhk->bshk", enc, bp["xattn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc, bp["xattn"]["wv"])
            x, aux = _dense_block(bp, cfg, x, positions, aux, causal=True,
                                  enc=(k, v))
            return (x, aux), None

        (x, _), _ = jax.lax.scan(_maybe_remat(body, remat), (x, {}), params["blocks"])
    else:
        raise ValueError(cfg.arch_type)

    x = layers.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    if cfg.arch_type == "vlm":                         # only text positions score
        x = x[:, batch["vision"].shape[1]:]
    return x, aux


def forward_embedded(params: Params, cfg: ArchConfig, x, *, positions=None,
                     remat: str = "none"):
    """Dense-stack forward over PRE-EMBEDDED inputs.

    x: (b, s, d_model) — e.g. projected observations rather than token
    embeddings.  Runs ``params["blocks"]`` + final norm and returns
    (features (b, s, d_model), aux).  Dense-family archs only.
    """
    if cfg.arch_type not in ("dense", "moe"):
        raise ValueError(
            f"forward_embedded supports dense/moe archs, got {cfg.arch_type}")
    if positions is None:
        positions = jnp.arange(x.shape[1])
    x, aux = _run_dense_stack(params["blocks"], cfg, x, positions, remat)
    return layers.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps), aux


def unembed_table(params: Params, cfg: ArchConfig):
    return params["embed"]["table"] if cfg.tie_embeddings else params["lm_head"]["table"]


def mask_pad_logits(logits, cfg: ArchConfig):
    """Vocab-pad entries get -inf so softmax/argmax ignore them."""
    if cfg.padded_vocab_size == cfg.vocab_size:
        return logits
    valid = jnp.arange(cfg.padded_vocab_size) < cfg.vocab_size
    return jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            *, remat: str = "full"):
    """Full logits over all (text) positions: (b, s, padded_V)."""
    x, aux = forward_features(params, cfg, batch, remat=remat)
    logits = layers.unembed(unembed_table(params, cfg), x)
    logits = mask_pad_logits(logits, cfg)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux


# ======================================================================
# Decode
# ======================================================================
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               layout: str = "stacked"):
    """Per-layer decode caches.

    ``layout="stacked"``: leading layers axis, decode scans over layers
    (compact HLO — CPU smoke tests).
    ``layout="list"``: a list of per-layer caches, decode unrolls — every
    cache buffer is updated in place with donation aliasing and no loop-state
    copies (production serving layout).  Dense-family archs only.
    """
    def stack(n, make):
        one = make()
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), one)

    if cfg.arch_type in ("dense", "moe", "vlm"):
        if layout == "list":
            return {"kv_list": [attn.init_kv_cache(cfg, batch, max_len, dtype)
                                for _ in range(cfg.num_layers)]}
        return {"kv": stack(cfg.num_layers,
                            lambda: attn.init_kv_cache(cfg, batch, max_len, dtype))}
    if cfg.arch_type == "ssm":
        return {"ssm": stack(cfg.num_layers,
                             lambda: ssm_lib.init_ssm_cache(cfg, batch))}
    if cfg.arch_type == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.num_layers, every)
        c = {
            "ssm": stack(n_groups * every, lambda: ssm_lib.init_ssm_cache(cfg, batch)),
            "attn_kv": stack(n_groups,
                             lambda: attn.init_kv_cache(cfg, batch, max_len, dtype)),
        }
        c["ssm"] = jax.tree.map(
            lambda x: x.reshape(n_groups, every, *x.shape[1:]), c["ssm"])
        if rem:
            c["tail_ssm"] = stack(rem, lambda: ssm_lib.init_ssm_cache(cfg, batch))
        return c
    if cfg.arch_type == "audio":
        return {
            "kv": stack(cfg.num_layers,
                        lambda: attn.init_kv_cache(cfg, batch, max_len, dtype)),
            # precomputed cross K/V per decoder layer (filled at prefill)
            "cross_k": jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                                  cfg.num_kv_heads, cfg.head_dim), dtype),
            "cross_v": jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                                  cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    raise ValueError(cfg.arch_type)


def _decode_dense_block(bp, cfg, x, kv_cache, pos, cross_kv=None,
                        backend="jnp"):
    h, kv_cache = attn.decode_attention(
        bp["attn"], cfg, layers.rmsnorm(bp["ln1"], x, cfg.rmsnorm_eps),
        kv_cache, pos, backend=backend)
    x = x + h
    if cross_kv is not None:
        h, _ = attn.decode_attention(
            bp["xattn"], cfg, layers.rmsnorm(bp["lnx"], x, cfg.rmsnorm_eps),
            None, pos, cross_kv=cross_kv)
        x = x + h
    y = layers.rmsnorm(bp["ln2"], x, cfg.rmsnorm_eps)
    if cfg.arch_type == "moe":
        f, _ = moe_lib.moe_ffn(bp["moe"], cfg, y)
    else:
        f = layers.mlp(bp["mlp"], y)
    return x + f, kv_cache


def _prefill_dense_block(bp, cfg, x, kv_cache, positions, lengths=None):
    """``_decode_dense_block``'s batched-prompt twin: the whole prompt's K/V
    lands in the cache in one attention call, not one call per token."""
    h, kv_cache = attn.prefill_attention(
        bp["attn"], cfg, layers.rmsnorm(bp["ln1"], x, cfg.rmsnorm_eps),
        kv_cache, positions, lengths=lengths)
    x = x + h
    y = layers.rmsnorm(bp["ln2"], x, cfg.rmsnorm_eps)
    if cfg.arch_type == "moe":
        f, _ = moe_lib.moe_ffn(bp["moe"], cfg, y)
    else:
        f = layers.mlp(bp["mlp"], y)
    return x + f, kv_cache


def _decode_ssm_block(bp, cfg, x, cache):
    h, cache = ssm_lib.ssm_step(bp["ssm"], cfg,
                                layers.rmsnorm(bp["ln"], x, cfg.rmsnorm_eps), cache)
    return x + h, cache


def decode_step(params: Params, cfg: ArchConfig, cache, token, pos, *,
                backend: str = "jnp"):
    """token: (b, 1) int32; pos: scalar int32 (or (b,) per-row positions for
    dense-family archs). Returns (logits (b, V), cache).  ``backend`` picks
    the decode-attention path (jnp | kernel | ref | auto) on dense archs.
    """
    x = layers.embed(params["embed"], token)
    x = shard(x, "batch", None, "d_model")

    if cfg.arch_type in ("dense", "moe", "vlm"):
        if "kv_list" in cache:      # unrolled serving layout
            new_list = []
            for i, kv in enumerate(cache["kv_list"]):
                bp = jax.tree.map(lambda p: p[i], params["blocks"])
                x, kv = _decode_dense_block(bp, cfg, x, kv, pos,
                                            backend=backend)
                new_list.append(kv)
            new_cache = {"kv_list": new_list}
        else:
            def body(x, layer_in):
                bp, kv = layer_in
                x, kv = _decode_dense_block(bp, cfg, x, kv, pos,
                                            backend=backend)
                return x, kv
            x, new_kv = jax.lax.scan(body, x, (params["blocks"], cache["kv"]))
            new_cache = {"kv": new_kv}

    elif cfg.arch_type == "ssm":
        def body(x, layer_in):
            bp, c = layer_in
            x, c = _decode_ssm_block(bp, cfg, x, c)
            return x, c
        x, new_c = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        new_cache = {"ssm": new_c}

    elif cfg.arch_type == "hybrid":
        shared = params["shared_attn"]

        def group(x, layer_in):
            gp, gc, kv = layer_in

            def inner(x, li):
                bp, c = li
                x, c = _decode_ssm_block(bp, cfg, x, c)
                return x, c
            x, gc = jax.lax.scan(inner, x, (gp, gc))
            x, kv = _decode_dense_block(shared, cfg, x, kv, pos)
            return x, (gc, kv)

        x, (new_ssm, new_kv) = jax.lax.scan(
            group, x, (params["blocks"], cache["ssm"], cache["attn_kv"]))
        new_cache = {"ssm": new_ssm, "attn_kv": new_kv}
        if "tail_blocks" in params:
            def body(x, li):
                bp, c = li
                x, c = _decode_ssm_block(bp, cfg, x, c)
                return x, c
            x, new_tail = jax.lax.scan(body, x, (params["tail_blocks"],
                                                 cache["tail_ssm"]))
            new_cache["tail_ssm"] = new_tail

    elif cfg.arch_type == "audio":
        # sinusoid positional embedding at position `pos`
        dim = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)
        angle = pos.astype(jnp.float32) / jnp.power(10000.0, dim / cfg.d_model)
        pe = jnp.zeros((cfg.d_model,), jnp.float32)
        pe = pe.at[0::2].set(jnp.sin(angle)).at[1::2].set(jnp.cos(angle))
        x = x + pe.astype(x.dtype)[None, None, :]

        def body(x, layer_in):
            bp, kv, ck, cv = layer_in
            x, kv = _decode_dense_block(bp, cfg, x, kv, pos, cross_kv=(ck, cv))
            return x, kv
        x, new_kv = jax.lax.scan(
            body, x, (params["blocks"], cache["kv"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, kv=new_kv)
    else:
        raise ValueError(cfg.arch_type)

    x = layers.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    logits = layers.unembed(unembed_table(params, cfg), x)[:, 0]
    logits = mask_pad_logits(logits, cfg)
    return shard(logits, "batch", "vocab"), new_cache


def prefill_embedded(params: Params, cfg: ArchConfig, cache, x, *,
                     lengths=None):
    """Batched prompt prefill over PRE-EMBEDDED inputs.

    x: (b, s, d_model) with s <= cache length; rows shorter than ``s`` are
    right-padded and masked out via ``lengths`` (b,) int32.  The whole
    prompt's K/V lands in the cache in ONE call per layer, so decode can
    continue at position ``lengths[i]`` without per-token replay.  Stacked
    ``"kv"`` cache layout, dense-family archs only.

    Returns (features (b, s, d_model), new_cache).
    """
    if cfg.arch_type not in ("dense", "moe"):
        raise ValueError(
            f"prefill_embedded supports dense/moe archs, got {cfg.arch_type}")
    if "kv" not in cache:
        raise ValueError("prefill_embedded needs the stacked 'kv' cache layout")
    positions = jnp.arange(x.shape[1])

    def body(x, layer_in):
        bp, kv = layer_in
        x, kv = _prefill_dense_block(bp, cfg, x, kv, positions,
                                     lengths=lengths)
        return x, kv
    x, new_kv = jax.lax.scan(body, x, (params["blocks"], cache["kv"]))
    return layers.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps), \
        {"kv": new_kv}


def decode_step_embedded(params: Params, cfg: ArchConfig, cache, x, pos, *,
                         backend: str = "jnp"):
    """Incremental decode over PRE-EMBEDDED inputs.

    x: (b, 1, d_model); pos: scalar int32 or per-row (b,) int32 positions
    (continuous batching — each row advances independently).  Stacked
    ``"kv"`` cache layout, dense-family archs only.

    Returns (features (b, d_model), new_cache).
    """
    if cfg.arch_type not in ("dense", "moe"):
        raise ValueError(
            f"decode_step_embedded supports dense/moe archs, got {cfg.arch_type}")
    if "kv" not in cache:
        raise ValueError(
            "decode_step_embedded needs the stacked 'kv' cache layout")

    def body(x, layer_in):
        bp, kv = layer_in
        x, kv = _decode_dense_block(bp, cfg, x, kv, pos, backend=backend)
        return x, kv
    x, new_kv = jax.lax.scan(body, x, (params["blocks"], cache["kv"]))
    x = layers.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    return x[:, 0], {"kv": new_kv}


def prefill(params: Params, cfg: ArchConfig, cache, tokens, *, lengths=None):
    """Batched token prefill: embed, run the stack through the cache, and
    return next-token logits at each row's last REAL token.

    tokens: (b, s) int32, right-padded; lengths: (b,) int32 real lengths
    (None means every row uses all ``s`` tokens).  Dense-family archs with
    the stacked ``"kv"`` cache layout only.

    Returns (logits (b, V), new_cache) — decode continues at position
    ``lengths[i]`` (or ``s``).
    """
    x = layers.embed(params["embed"], tokens)
    x = shard(x, "batch", None, "d_model")
    feats, new_cache = prefill_embedded(params, cfg, cache, x,
                                        lengths=lengths)
    if lengths is None:
        last = feats[:, -1]
    else:
        rows = jnp.arange(feats.shape[0])
        last = feats[rows, jnp.maximum(lengths - 1, 0)]
    logits = layers.unembed(unembed_table(params, cfg), last)
    logits = mask_pad_logits(logits, cfg)
    return shard(logits, "batch", "vocab"), new_cache
