"""Mixture-of-Experts FFN with GShard-style capacity dispatch.

TPU-native formulation: tokens are bucketed into small groups (GROUP tokens
each); within a group each token's top-k experts are assigned a slot in a
fixed per-expert capacity buffer, and dispatch/combine are einsums — fully
shardable under SPMD (expert ffn dim on the ``model`` mesh axis; groups follow
the batch onto ``data``).  Keeping groups small (256) keeps the dispatch
one-hot einsum at <10-20% of the expert matmul FLOPs.

Includes the load-balance auxiliary loss (Switch/GShard) and router z-loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ArchConfig, MoEConfig
from repro.sharding import shard

GROUP = 256


def moe_init(key, cfg: ArchConfig, dtype=jnp.float32):
    m = cfg.moe
    d, f = cfg.d_model, m.d_expert
    ks = jax.random.split(key, 5)

    def bank(k, n):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "w_gate": layers.truncated_normal(k1, (n, d, f), d ** -0.5, dtype),
            "w_up": layers.truncated_normal(k2, (n, d, f), d ** -0.5, dtype),
            "w_down": layers.truncated_normal(k3, (n, f, d), f ** -0.5, dtype),
        }

    p = {
        "router": layers.truncated_normal(ks[0], (d, m.num_experts), d ** -0.5, jnp.float32),
        "experts": bank(ks[1], m.num_experts),
    }
    if m.num_shared:
        # shared experts are always-on: fuse them into one wide ffn
        p["shared"] = layers.mlp_init(ks[2], d, f * m.num_shared, dtype)
    return p


def _expert_ffn(bank, x):
    """x: (e, g, c, d) -> (e, g, c, d) through per-expert SwiGLU."""
    h = jnp.einsum("egcd,edf->egcf", x, bank["w_gate"])
    h = shard(h, "experts", "moe_groups", None, "expert_ff")
    u = jnp.einsum("egcd,edf->egcf", x, bank["w_up"])
    u = shard(u, "experts", "moe_groups", None, "expert_ff")
    h = shard(jax.nn.silu(h) * u, "experts", "moe_groups", None, "expert_ff")
    out = jnp.einsum("egcf,efd->egcd", h, bank["w_down"])
    return shard(out, "experts", "moe_groups", None, "d_model")


def moe_ffn(params, cfg: ArchConfig, x):
    """x: (b, s, d). Returns (y, aux_losses dict)."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    tokens = b * s
    g_tokens = min(m.group_size, s)
    n_groups = tokens // g_tokens
    capacity = math.ceil(g_tokens * k * m.capacity_factor / e) if e else 0
    capacity = max(capacity, k)

    # gather the sequence-parallel shards BEFORE grouping so the group dim
    # carries only the batch axes (consistent with the expert einsums).
    x = shard(x, "batch", "seq", "d_model")
    xg = x.reshape(n_groups, g_tokens, d)
    xg = shard(xg, "moe_groups", None, "d_model")

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (g,t,e)

    top_vals, top_idx = jax.lax.top_k(probs, k)                  # (g,t,k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # expert assignment mask, rank-major priority for capacity slots
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)       # (g,t,k,e)
    rank_major = jnp.moveaxis(onehot, 2, 1).reshape(n_groups, k * g_tokens, e)
    pos = jnp.cumsum(rank_major, axis=1) - 1.0                   # slot per assignment
    pos = jnp.moveaxis(pos.reshape(n_groups, k, g_tokens, e), 1, 2)  # (g,t,k,e)
    keep = (pos < capacity) & (onehot > 0)
    slot = jnp.sum(pos * onehot, axis=-1)                        # (g,t,k)

    slot_oh = jax.nn.one_hot(slot.astype(jnp.int32), capacity,
                             dtype=x.dtype)                      # (g,t,k,c)
    kept = jnp.sum(keep, axis=-1).astype(x.dtype)                # (g,t,k)
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot.astype(x.dtype) *
                          kept[..., None], slot_oh)              # (g,t,e,c)
    dispatch = shard(dispatch, "moe_groups", None, None, None)
    # combine weights: scale each kept assignment by its gate value
    gate_per_expert = jnp.einsum("gtke,gtk->gte", onehot.astype(x.dtype),
                                 top_vals.astype(x.dtype))
    combine = dispatch * gate_per_expert[..., None]

    expert_in = jnp.einsum("gtec,gtd->egcd", dispatch, xg)
    expert_in = shard(expert_in, "experts", "moe_groups", None, "d_model")
    expert_out = _expert_ffn(params["experts"], expert_in)
    y = jnp.einsum("gtec,egcd->gtd", combine, expert_out)

    if m.num_shared:
        y = y + layers.mlp(params["shared"], xg)

    # aux losses (computed per group, then averaged)
    me = jnp.mean(probs, axis=1)                                 # (g,e) router prob mass
    ce = jnp.mean(onehot.sum(2), axis=1)                         # (g,e) fraction routed
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    losses = {"moe_aux": m.router_aux_weight * aux,
              "moe_z": m.router_z_weight * z}
    return y.reshape(b, s, d), losses
