"""GQA attention: chunked-causal training/prefill path + single-token decode.

The training/prefill path scans over query chunks (flash-style: never
materializes the full (S, S) score matrix) so that 32k-token prefill lowers
with O(S * chunk) live memory.  Supports RoPE, Qwen3 qk-norm, sliding-window
(banded) masking, and non-causal/cross attention for the Whisper encoder.

GQA K/V are stored with ``num_kv_heads`` (cache compression) and broadcast to
the full head count at compute time — the broadcast keeps every score tensor
laid out (batch, heads, q, k) so SPMD head-sharding propagates cleanly.

Positions are 1-D ``(seq,)`` — shared across the batch — on the training and
prefill paths; ``decode_attention`` additionally accepts per-row ``(b,)``
positions so continuous-batching servers can decode requests that are at
different depths of their episodes in ONE dispatch.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ArchConfig
from repro.sharding import shard

NEG_INF = -1e30


def attn_init(key, cfg: ArchConfig, dtype=jnp.float32):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.truncated_normal(ks[0], (d, h, hd), d ** -0.5, dtype),
        "wk": layers.truncated_normal(ks[1], (d, kv, hd), d ** -0.5, dtype),
        "wv": layers.truncated_normal(ks[2], (d, kv, hd), d ** -0.5, dtype),
        "wo": layers.truncated_normal(ks[3], (h, hd, d), (h * hd) ** -0.5, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(params, cfg: ArchConfig, x, positions, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = layers.head_rmsnorm(params["q_norm"], q, cfg.rmsnorm_eps)
        k = layers.head_rmsnorm(params["k_norm"], k, cfg.rmsnorm_eps)
    if rope and cfg.rope_theta > 0:
        # positions: (s,) shared across the batch, or (b, s) per-row
        pos2d = positions if positions.ndim == 2 else positions[None, :]
        q = layers.apply_rope(q, pos2d, cfg.rope_theta)
        k = layers.apply_rope(k, pos2d, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "kv_seq", "kv_heads", "head_dim")
    return q, k, v


def _repeat_kv(k, q_per_kv: int):
    """(b, s, kv, hd) -> (b, s, h, hd), sharded on the full head axis."""
    if q_per_kv == 1:
        return k
    k = jnp.repeat(k, q_per_kv, axis=2)
    return shard(k, "batch", "kv_seq", "heads", "head_dim")


def _masked_softmax(scores, q_pos, k_pos, causal, window):
    """scores: (b, h, sq, sk); q_pos: (sq,), k_pos: (sk,)."""
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    return jax.nn.softmax(scores.astype(jnp.float32), axis=-1)


def attention(params, cfg: ArchConfig, x, positions, *, causal=True,
              q_chunk: int = 1024, kv: Optional[tuple] = None):
    """Full-sequence attention. ``kv`` overrides K/V (cross-attention)."""
    q, k, v = _project_qkv(params, cfg, x, positions, rope=kv is None)
    if kv is not None:
        k, v = kv
    sq, sk = q.shape[1], k.shape[1]
    k_pos = positions if kv is None else jnp.arange(sk)
    window = cfg.sliding_window
    k = _repeat_kv(k, cfg.q_per_kv)
    v = _repeat_kv(v, cfg.q_per_kv)
    scale = cfg.head_dim ** -0.5

    # sequence-parallel attention: when the q_seq rule maps to a mesh axis
    # (heads not divisible by the model axis), shard query positions and
    # compute un-chunked — scores are (b, h, sq/P, sk), already small.
    # K/V are gathered to full sequence (replicated heads) and every score
    # tensor is pinned to q_seq — otherwise the einsum's two free dims both
    # want the model axis and the partitioner replicates the full (sq, sk)
    # matrix.
    from repro.sharding import current_rules
    rules = current_rules()
    seq_par = False
    if rules is not None:
        spec = rules.mesh_axes(("q_seq",), (sq,))
        if spec and spec[0] is not None:
            seq_par = True
            q = shard(q, "batch", "q_seq", "heads", "head_dim")
            k = shard(k, "batch", None, None, None)
            v = shard(v, "batch", None, None, None)
            q_chunk = sq

    def block(q_blk, pos_blk):
        scores = jnp.einsum("bqhk,bshk->bhqs", q_blk, k) * scale
        if seq_par:
            scores = shard(scores, "batch", None, "q_seq", None)
        else:
            scores = shard(scores, "batch", "heads", None, None)
        p = _masked_softmax(scores, pos_blk, k_pos, causal, window).astype(v.dtype)
        if seq_par:
            p = shard(p, "batch", None, "q_seq", None)
        out = jnp.einsum("bhqs,bshk->bqhk", p, v)
        if seq_par:
            return shard(out, "batch", "q_seq", "heads", "head_dim")
        return shard(out, "batch", None, "heads", "head_dim")

    if sq % q_chunk != 0:
        q_chunk = sq          # non-divisible (e.g. whisper's 1500 frames)
    if sq <= q_chunk:
        out = block(q, positions)
    else:
        n = sq // q_chunk
        qs = jnp.moveaxis(q.reshape(q.shape[0], n, q_chunk, *q.shape[2:]), 1, 0)
        ps = positions.reshape(n, q_chunk)
        out = jax.lax.map(lambda args: block(*args), (qs, ps))
        out = jnp.moveaxis(out, 0, 1).reshape(q.shape[0], sq, q.shape[2], q.shape[3])
    out = shard(out, "batch", "seq", "heads", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# ------------------------------------------------------------------ decode
def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """One layer's cache. Sliding-window archs use a ring buffer of size W."""
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, length, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _decode_backend(backend: str, length: int) -> str:
    """Resolve ``"auto"`` to a concrete decode backend.

    The pallas flash-decoding kernel requires the cache length to divide its
    k-block, and interpret mode (how pallas runs off-TPU) is far slower than
    plain jnp — so ``auto`` picks the kernel only on a real TPU and falls
    back to the pure-jnp ``kernels/ref.py`` oracle everywhere else.
    """
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "ref"
    if backend == "kernel" and length % min(512, length) != 0:
        backend = "ref"
    return backend


def decode_attention(params, cfg: ArchConfig, x, cache, pos, *,
                     cross_kv: Optional[tuple] = None, backend: str = "jnp"):
    """One-token decode. x: (b, 1, d); pos: scalar int32 (current index) or
    ``(b,)`` int32 per-row positions (continuous batching: rows at different
    episode depths decoded in one dispatch).

    K is stored pre-RoPE'd.  Returns (out, new_cache).
    For ``cross_kv`` (whisper) the cache is passed through untouched.

    ``backend`` selects the score/softmax path once the cache is updated:
    ``"jnp"`` (grouped-GQA einsum), ``"kernel"`` (the pallas flash-decoding
    kernel — MHA layout, per-row valid prefix lengths), ``"ref"`` (the
    pure-jnp ``kernels/ref.py`` oracle, the CPU fallback), or ``"auto"``
    (kernel on TPU when the cache length divides the k-block, ref elsewhere).
    """
    pos = jnp.asarray(pos, jnp.int32)
    vector_pos = pos.ndim == 1
    positions = pos[:, None] if vector_pos else jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions, rope=cross_kv is None)
    scale = cfg.head_dim ** -0.5

    def score_softmax_out(k, v, valid):
        # grouped GQA (no KV repeat): with sq == 1 every tensor here is tiny
        # except the cache itself, which is read exactly once.
        if k.dtype != q.dtype:      # quantized (e.g. f8) caches: upcast fuses
            k = k.astype(q.dtype)
            v = v.astype(q.dtype)
        b = q.shape[0]
        qg = q.reshape(b, 1, k.shape[2], cfg.q_per_kv, cfg.head_dim)
        scores = jnp.einsum("bqngh,bsnh->bngqs", qg, k) * scale
        if valid is not None:
            vshape = ((valid.shape[0], 1, 1, 1, -1) if valid.ndim == 2
                      else (1, 1, 1, 1, -1))
            scores = jnp.where(valid.reshape(vshape), scores, NEG_INF)
        p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(v.dtype)
        out = jnp.einsum("bngqs,bsnh->bqngh", p, v)
        out = out.reshape(b, 1, cfg.num_heads, cfg.head_dim)
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"])

    if cross_kv is not None:
        k, v = cross_kv
        return score_softmax_out(k, v, None), cache

    length = cache["k"].shape[1]
    slot = jnp.mod(pos, length) if cfg.sliding_window else pos
    if vector_pos:
        rows = jnp.arange(k_new.shape[0])
        k = cache["k"].at[rows, slot].set(k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[rows, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    else:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), slot, 1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), slot, 1)
    new_cache = {"k": k, "v": v}

    backend = _decode_backend(backend, length)
    if backend in ("kernel", "ref"):
        # Both kernels mask a VALID PREFIX per row.  That is exactly the
        # occupancy of our caches: a linear cache holds slots [0, pos] and a
        # full ring holds all L slots — min(pos+1, L) either way.  Ring
        # wraparound scrambles chronological order, but softmax attention is
        # permutation-invariant over the key set and K is stored post-RoPE,
        # so prefix masking stays correct after wrap.
        lengths = jnp.broadcast_to(jnp.minimum(pos + 1, length),
                                   (q.shape[0],)).astype(jnp.int32)
        kf = _repeat_kv(k.astype(q.dtype), cfg.q_per_kv)
        vf = _repeat_kv(v.astype(q.dtype), cfg.q_per_kv)
        if backend == "kernel":
            from repro.kernels import ops
            out_h = ops.decode_attention(q[:, 0], kf, vf, lengths,
                                         block_k=min(512, length))
        else:
            from repro.kernels import ref as kernels_ref
            out_h = kernels_ref.decode_attention_ref(q[:, 0], kf, vf, lengths)
        out = jnp.einsum("bhk,hkd->bd", out_h.astype(q.dtype),
                         params["wo"])[:, None]
        return out, new_cache
    if backend != "jnp":
        raise ValueError(f"unknown decode backend {backend!r}")

    slots = jnp.arange(length)
    pos_col = pos[:, None] if vector_pos else pos
    if cfg.sliding_window:
        # slot s holds token pos - ((pos - s) mod L); valid if that is >= 0
        token_idx = pos_col - jnp.mod(pos_col - slots, length)
        valid = token_idx >= 0
    else:
        valid = slots <= pos_col
    return score_softmax_out(k, v, valid), new_cache


def prefill_attention(params, cfg: ArchConfig, x, cache, positions,
                      lengths=None):
    """Batched prompt prefill THROUGH the decode cache: one call writes the
    whole prompt's K/V into slots [0, s) and returns full-sequence outputs.

    x: (b, s, d); positions: (s,) shared across rows (prompts are
    left-aligned at 0..s-1); lengths: optional (b,) valid prompt lengths —
    keys at or beyond a row's length are masked out (shorter prompts and
    zero-padded batch slots), though their outputs are still computed
    (callers read only positions < length).  Returns (out, new_cache).

    The prompt must fit the cache (s <= cache length): continuous-batching
    callers re-prefill from a bounded window rather than wrap mid-prompt.
    """
    s = x.shape[1]
    length = cache["k"].shape[1]
    if s > length:
        raise ValueError(f"prompt of {s} tokens exceeds cache length {length}")
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    k_cache = cache["k"].at[:, :s].set(k_new.astype(cache["k"].dtype))
    v_cache = cache["v"].at[:, :s].set(v_new.astype(cache["v"].dtype))
    new_cache = {"k": k_cache, "v": v_cache}

    k = _repeat_kv(k_new, cfg.q_per_kv)
    v = _repeat_kv(v_new, cfg.q_per_kv)
    scale = cfg.head_dim ** -0.5
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k) * scale
    mask = positions[:, None] >= positions[None, :]
    if cfg.sliding_window is not None:
        mask &= (positions[:, None] - positions[None, :]) < cfg.sliding_window
    mask = mask[None, None]                                # (1, 1, s, s)
    if lengths is not None:
        mask = mask & (positions[None, None, None, :]
                       < lengths[:, None, None, None])
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshk->bqhk", p, v)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"]), new_cache
