"""Mamba2 block via SSD (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute *within* fixed-size chunks plus a linear recurrence *across* chunk
states — O(S * chunk) instead of O(S^2), and a natural fit for TPU MXU
(all heavy ops are batched matmuls).  Decode is the constant-memory
selective-state recurrence (h <- a*h + dt*B*x) plus a rolling conv state.

Head layout follows Mamba2: d_inner = expand*d_model split into H heads of
P=head_dim channels; B and C are shared across heads (single group, like MQA);
per-head scalar dt and A.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ArchConfig
from repro.sharding import shard


def ssm_init(key, cfg: ArchConfig, dtype=jnp.float32):
    s, d = cfg.ssm, cfg.d_model
    di = s.d_inner(d)
    nh = s.num_heads(d)
    n = s.d_state
    conv_dim = di + 2 * n                       # x + B + C go through the conv
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,), minval=jnp.log(1e-3),
                                    maxval=jnp.log(1e-1)))
    return {
        # order: [z (di), x (di), B (n), C (n), dt (nh)]
        "in_proj": layers.truncated_normal(ks[0], (d, 2 * di + 2 * n + nh),
                                           d ** -0.5, dtype),
        "conv_w": layers.truncated_normal(ks[1], (s.conv_width, conv_dim),
                                          0.1, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "dt_bias": jnp.log(jnp.expm1(dt)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": layers.rmsnorm_init(di, dtype),
        "out_proj": layers.truncated_normal(ks[3], (di, d), di ** -0.5, dtype),
    }


def _split_proj(cfg: ArchConfig, proj):
    s = cfg.ssm
    di, n = s.d_inner(cfg.d_model), s.d_state
    nh = s.num_heads(cfg.d_model)
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * n], axis=-1)
    assert dt.shape[-1] == nh
    return z, xbc, dt


def _causal_conv(w, b, xbc):
    """Depthwise causal conv over (b, s, c)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, w[:, None, :].astype(xbc.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xbc.shape[-1])
    return jax.nn.silu(out + b.astype(out.dtype))


def _segsum(x):
    """Stable 'segment sum' producing the lower-triangular decay matrix.

    x: (..., q) per-step log decays -> L[..., i, j] = sum_{j<k<=i} x[k],
    masked to -inf above the diagonal.
    """
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(xh, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD scan (pure jnp oracle for the Pallas kernel, and the
    default XLA path in the model).

    xh: (b, s, h, p)   per-head inputs
    dt: (b, s, h)      softplus'd step sizes (>0)
    A:  (h,)           negative per-head decay rates
    B:  (b, s, n)      input projection (single group)
    C:  (b, s, n)      output projection
    Returns (y: (b, s, h, p), final_state: (b, h, n, p)).
    """
    b, s, h, p = xh.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def r(t, tail):  # reshape into chunks
        return t.reshape((b, nc, chunk) + tail)

    xh_c = r(xh, (h, p)).astype(jnp.float32)
    dt_c = r(dt, (h,)).astype(jnp.float32)
    B_c = r(B, (n,)).astype(jnp.float32)
    C_c = r(C, (n,)).astype(jnp.float32)

    dA = dt_c * A[None, None, None, :]               # (b,nc,q,h) log decays
    dA_cum = jnp.cumsum(dA, axis=2)                  # within-chunk cumulative

    # 1) intra-chunk (diagonal block) — quadratic within chunk
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))   # (b,nc,h,q,q)
    scores = jnp.einsum("bcqn,bckn->bcqk", C_c, B_c) # (b,nc,q,k)
    M = scores[:, :, None] * L                       # (b,nc,h,q,k)
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dt_c, xh_c)

    # 2) chunk end-states: decay-weighted sum of inputs
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)   # (b,nc,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                        B_c, dt_c * decay_to_end, xh_c)     # (b,nc,h,n,p)

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])               # (b,nc,h)
    init = jnp.zeros((b, h, n, p), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(hprev, inp):
        st, dec = inp
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    (final, prev_states) = jax.lax.scan(
        step, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (b,nc,h,n,p) state entering chunk

    # 4) inter-chunk contribution
    state_decay = jnp.exp(dA_cum)                            # decay from chunk start
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", C_c, state_decay, prev_states)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssm_forward(params, cfg: ArchConfig, x, state=None):
    """Full-sequence Mamba2 block. x: (b, s, d) -> (y, final_state)."""
    s_cfg = cfg.ssm
    di = s_cfg.d_inner(cfg.d_model)
    nh = s_cfg.num_heads(cfg.d_model)
    n, p = s_cfg.d_state, s_cfg.head_dim

    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = _causal_conv(params["conv_w"], params["conv_b"], xbc)
    xi, B, C = jnp.split(xbc, [di, di + n], axis=-1)
    xh = xi.reshape(*xi.shape[:2], nh, p)
    xh = shard(xh, "batch", "seq", "ssm_heads", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y, final = ssd_chunked(xh, dt, A, B, C, min(s_cfg.chunk_size, x.shape[1]))
    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.rmsnorm_eps)
    return jnp.einsum("bsi,id->bsd", y, params["out_proj"]), final


# ------------------------------------------------------------------ decode
def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    nh = s.num_heads(cfg.d_model)
    conv_dim = s.d_inner(cfg.d_model) + 2 * s.d_state
    return {
        "state": jnp.zeros((batch, nh, s.d_state, s.head_dim), dtype),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }


def ssm_step(params, cfg: ArchConfig, x, cache):
    """One-token decode. x: (b, 1, d). Returns (y, new_cache)."""
    s_cfg = cfg.ssm
    di = s_cfg.d_inner(cfg.d_model)
    nh = s_cfg.num_heads(cfg.d_model)
    n, p = s_cfg.d_state, s_cfg.head_dim

    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, proj)

    # rolling conv: window = [cached (w-1), current]
    window = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    xbc1 = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))[:, None, :]
    new_conv = window[:, 1:]

    xi, B, C = jnp.split(xbc1, [di, di + n], axis=-1)
    xh = xi.reshape(xi.shape[0], nh, p)                  # (b,h,p)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (b,h)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A[None, :])                         # (b,h)

    h_prev = cache["state"].astype(jnp.float32)
    Bx = jnp.einsum("bn,bhp,bh->bhnp", B[:, 0].astype(jnp.float32), xh, dt)
    h_new = h_prev * a[..., None, None] + Bx
    y = jnp.einsum("bn,bhnp->bhp", C[:, 0].astype(jnp.float32), h_new)
    y = y + xh * params["D"][None, :, None]
    y = y.reshape(x.shape[0], 1, di).astype(x.dtype)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.rmsnorm_eps)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    return out, {"state": h_new.astype(cache["state"].dtype), "conv": new_conv}
