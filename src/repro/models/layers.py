"""Shared neural-net building blocks (pure functions over param pytrees)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import shard


def truncated_normal(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def dense_init(key, d_in, d_out, dtype=jnp.float32):
    return truncated_normal(key, (d_in, d_out), d_in ** -0.5, dtype)


# ---------------------------------------------------------------- RMSNorm
def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(scale, x, eps=1e-6):
    """RMSNorm over the last (head_dim) axis, per head — Qwen3 qk-norm."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    angles = angles[..., None, :]                       # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(seq_len: int, d_model: int):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ---------------------------------------------------------------- SwiGLU MLP
def mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params, x):
    h = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = shard(jax.nn.silu(h) * u, "batch", "seq", "ff")
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------- Embedding
def embed_init(key, vocab, d_model, dtype=jnp.float32):
    return {"table": truncated_normal(key, (vocab, d_model), 1.0, dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(table, x):
    return jnp.einsum("...d,vd->...v", x, table)


def chunked_cross_entropy(x, table, labels, chunk: int = 1024,
                          mask: Optional[jax.Array] = None,
                          valid_vocab: Optional[int] = None):
    """Mean next-token CE without materializing full (b, s, V) f32 logits.

    x: (b, s, d) final hidden states; table: (V, d); labels: (b, s).
    Scans seq chunks; each chunk's logits are rematerialized in the backward
    pass (jax.checkpoint), so live memory is O(b * chunk * V).
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk

    def chunk_loss(xc, lc, mc):
        logits = unembed(table, xc).astype(jnp.float32)
        if valid_vocab is not None and valid_vocab < table.shape[0]:
            logits = jnp.where(jnp.arange(table.shape[0]) < valid_vocab,
                               logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - ll) * mc
        return jnp.sum(nll)

    chunk_loss = jax.checkpoint(chunk_loss)
    mask_f = jnp.ones((b, s), jnp.float32) if mask is None else mask.astype(jnp.float32)

    xs = x[:, :n * chunk].reshape(b, n, chunk, d).swapaxes(0, 1)
    ls = labels[:, :n * chunk].reshape(b, n, chunk).swapaxes(0, 1)
    ms = mask_f[:, :n * chunk].reshape(b, n, chunk).swapaxes(0, 1)

    def body(tot, inp):
        return tot + chunk_loss(*inp), None

    total, _ = jax.lax.scan(body, jnp.float32(0), (xs, ls, ms))
    if rem:
        total = total + chunk_loss(x[:, n * chunk:], labels[:, n * chunk:],
                                   mask_f[:, n * chunk:])
    return total / jnp.maximum(jnp.sum(mask_f), 1.0)


def cross_entropy(logits, labels, mask: Optional[jax.Array] = None):
    """Mean next-token cross entropy in f32. logits (..., V), labels (...,)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
