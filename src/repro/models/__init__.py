from repro.models import attention, config, layers, moe, ssm, transformer  # noqa: F401
from repro.models.config import ArchConfig, InputShape, MoEConfig, SSMConfig  # noqa: F401
