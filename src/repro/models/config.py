"""Architecture configuration for the assigned model pool.

One frozen dataclass describes every family we support: dense/GQA decoders,
MoE, Mamba2 SSM, Zamba2-style hybrids, VLM decoders with stubbed vision
frontends, and Whisper-style encoder-decoders.  Per-arch instances live in
``repro.configs.<arch>``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    top_k: int
    d_expert: int               # per-expert ffn hidden size
    num_shared: int = 0         # always-on shared experts (same d_expert)
    capacity_factor: float = 1.25
    group_size: int = 256       # tokens per dispatch group (perf knob: the
                                # dispatch einsum costs g*k*cf*D MACs/token)
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-4


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int                # N — SSM state size per head
    head_dim: int = 64          # P — channels per SSM head
    expand: int = 2             # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256       # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int              # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int                   # dense ffn hidden (0 when pure MoE / ssm)
    vocab_size: int
    head_dim: int = 128
    # Attention flavour
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # set => banded attention
    # Family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: indices (into num_layers mamba stack) after which the *shared*
    # attention block is applied (Zamba2-style: one weight set, many sites).
    hybrid_attn_every: int = 0
    # encoder-decoder (whisper): encoder layers share d_model/heads/d_ff
    encoder_layers: int = 0
    encoder_seq: int = 0        # precomputed frame-embedding length (stub)
    # vlm: number of prefix patch-embedding tokens supplied by the stub
    vision_tokens: int = 0
    # norm/act
    rmsnorm_eps: float = 1e-6
    tie_embeddings: bool = False
    source: str = ""            # citation

    # ------------------------------------------------------------------
    @property
    def padded_vocab_size(self) -> int:
        """Embedding/LM-head tables are padded to a multiple of 256 so the
        vocab dim always divides the model mesh axis (Megatron-style)."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.arch_type == "hybrid"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def num_params(self) -> int:
        """Total parameter count (embedding included)."""
        d, L = self.d_model, self.num_layers
        p = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            p += self.vocab_size * d                  # lm head
        attn = d * self.num_heads * self.head_dim \
            + 2 * d * self.num_kv_heads * self.head_dim \
            + self.num_heads * self.head_dim * d
        ffn_dense = 3 * d * self.d_ff if self.d_ff else 0
        per_layer = 0
        if self.arch_type in ("dense", "vlm"):
            per_layer = attn + ffn_dense + 2 * d
        elif self.arch_type == "moe":
            m = self.moe
            routed = m.num_experts * 3 * d * m.d_expert
            shared = m.num_shared * 3 * d * m.d_expert
            router = d * m.num_experts
            per_layer = attn + routed + shared + router + 2 * d
        elif self.arch_type == "ssm":
            per_layer = self._ssm_params() + d
        elif self.arch_type == "hybrid":
            per_layer = self._ssm_params() + d
            n_sites = L // max(self.hybrid_attn_every, 1)
            # one shared attn+mlp block, counted once
            p += attn + ffn_dense + 2 * d
            del n_sites
        p += per_layer * L
        if self.is_encdec:
            # encoder self-attn+ffn, decoder cross-attn
            p += self.encoder_layers * (attn + ffn_dense + 2 * d)
            p += L * (attn + d)  # cross attention + its norm
        p += d  # final norm
        return p

    def num_active_params(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.arch_type != "moe":
            return self.num_params()
        m = self.moe
        d, L = self.d_model, self.num_layers
        inactive = (m.num_experts - m.top_k) * 3 * d * m.d_expert * L
        return self.num_params() - inactive

    def _ssm_params(self) -> int:
        s, d = self.ssm, self.d_model
        di = s.d_inner(d)
        nh = s.num_heads(d)
        n = s.d_state
        in_proj = d * (2 * di + 2 * n + nh)       # z, x, B, C, dt (B/C: 1 group)
        conv = (s.conv_width + 1) * (di + 2 * n)  # depthwise conv + bias
        out = di * d
        extra = 3 * nh + di                       # A_log, dt_bias, D, norm
        return in_proj + conv + out + extra


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
