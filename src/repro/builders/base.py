"""The formal builder protocol (§2.2/§2.4 of the paper).

Acme's central design claim is that ONE builder yields both the
single-process agent and the distributed program.  ``AgentBuilder`` turns
the seed's informal duck-typed convention into a typed contract:

  make_replay()            -> Table           (replay buffer / queue)
  make_adder(table)        -> Adder | None    (None for offline builders)
  make_dataset(table)      -> learner batch iterator
  make_learner(it, cb)     -> Learner
  make_policy(evaluation)  -> policy fn (or None for planning actors)
  make_actor(policy, client, adder, seed) -> Actor

plus a frozen ``BuilderOptions`` bundle replacing the loose
``variable_update_period`` / ``min_observations`` / ``observations_per_step``
instance attributes that every agent used to hand-roll.  Execution layers
(``repro.agents.builders``, ``repro.experiments``) consume only this
contract, so new execution modes (offline-only, evaluator fleets, async
actors) never require per-agent edits.

Concrete subclasses self-register; ``registered_builders()`` is the basis
of the conformance test in ``tests/test_builders_api.py``.
"""
from __future__ import annotations

import abc
import dataclasses
import inspect
from typing import Any, Dict, Iterator, List, Optional, Type


@dataclasses.dataclass(frozen=True)
class BuilderOptions:
    """Execution-schedule knobs shared by every agent.

    variable_update_period: actor->learner weight-sync cadence (in actor
        ``update()`` calls).
    min_observations: observations before the first learner step (the
        single-process analogue of the rate limiter's min_size_to_sample).
    observations_per_step: observations per learner step (the synchronous
        samples-per-insert schedule, §2.5).
    batch_size: learner batch size — used by execution layers to decide
        whether a consuming (queue) dataset can serve a full batch.
    offline: the builder learns from a fixed dataset; it has no adder and
        its actors never feed replay (§2.6).
    num_replay_shards: replay shards the execution layer builds from
        ``make_replay`` (1 = single table; >1 = ``ShardedReplay`` with one
        full table + selector + rate limiter per shard).
    prefetch_size: learner-side prefetch queue depth in batches (0 = the
        synchronous dataset; >0 wraps it in a ``PrefetchingDataset`` on the
        distributed learner hot path).
    num_envs_per_actor: environments each actor drives through a
        ``VectorEnv`` + batched actor (1 = the classic single-env loop;
        N > 1 = one vmapped policy dispatch per N env transitions).
    inference: where actor policy evaluation runs in distributed programs —
        ``"local"`` (each actor evaluates its own policy copy) or
        ``"server"`` (SEED-style: actors RPC a central ``InferenceServer``
        that coalesces requests into batched forward passes).
    num_learner_replicas: learner replicas the execution layer builds from
        ``make_learner`` (1 = the classic single SGD stream; N > 1 = one
        replica per replay shard, periodically merged by parameter
        averaging — actors and checkpoints still see one logical learner).
    learner_average_period: per-replica SGD steps between parameter-
        averaging rounds (params, target params, optimizer state, and step
        counters are all element-wise averaged).
    learner_sync: how replicas exchange parameters — ``"barrier"`` (strict
        all-or-nothing rendezvous), ``"quorum"`` (barrier with a timeout:
        needs ``barrier_timeout_s`` at the experiment layer), or
        ``"async"`` (push/pull ``AsyncParameterService``: each replica
        pushes at its own cadence and pulls the latest staleness-weighted
        blend, never waiting for peers).  ``"async"`` engages the
        multi-learner machinery even at one replica (the parity case).
    replay_routing: how inserts are routed across replay shards —
        ``"round_robin"`` (default), ``"hash"``, or ``"affinity"``
        (vectorized actors write each env's stream straight to its
        assigned shard through per-env ``ShardWriter``s).
    telemetry: enable ``repro.telemetry`` for this agent's runs — every
        process records RPC latencies, queue waits, block times etc. into
        its ``MetricRegistry`` and pushes snapshots to a run-wide
        ``MetricsHub``.  Off by default: disabled metrics are no-op nulls.
    telemetry_push_period_s: seconds between a worker's snapshot pushes to
        the hub.
    """

    variable_update_period: int = 10
    min_observations: int = 0
    observations_per_step: float = 1.0
    batch_size: int = 1
    offline: bool = False
    num_replay_shards: int = 1
    prefetch_size: int = 0
    num_envs_per_actor: int = 1
    inference: str = "local"
    num_learner_replicas: int = 1
    learner_average_period: int = 50
    learner_sync: str = "barrier"
    replay_routing: str = "round_robin"
    telemetry: bool = False
    telemetry_push_period_s: float = 0.5

    def __post_init__(self):
        if self.variable_update_period < 1:
            raise ValueError(
                f"variable_update_period must be >= 1, got "
                f"{self.variable_update_period}")
        if self.min_observations < 0:
            raise ValueError(
                f"min_observations must be >= 0, got {self.min_observations}")
        if self.observations_per_step <= 0:
            raise ValueError(
                f"observations_per_step must be > 0, got "
                f"{self.observations_per_step}")
        if self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {self.batch_size}")
        if self.num_replay_shards < 1:
            raise ValueError(
                f"num_replay_shards must be >= 1, got "
                f"{self.num_replay_shards}")
        if self.prefetch_size < 0:
            raise ValueError(
                f"prefetch_size must be >= 0, got {self.prefetch_size}")
        if self.num_envs_per_actor < 1:
            raise ValueError(
                f"num_envs_per_actor must be >= 1, got "
                f"{self.num_envs_per_actor}")
        if self.inference not in ("local", "server"):
            raise ValueError(
                f"inference must be 'local' or 'server', got "
                f"{self.inference!r}")
        if self.num_learner_replicas < 1:
            raise ValueError(
                f"num_learner_replicas must be >= 1, got "
                f"{self.num_learner_replicas}")
        if self.learner_average_period < 1:
            raise ValueError(
                f"learner_average_period must be >= 1, got "
                f"{self.learner_average_period}")
        if self.learner_sync not in ("barrier", "quorum", "async"):
            raise ValueError(
                f"learner_sync must be 'barrier', 'quorum' or 'async', got "
                f"{self.learner_sync!r}")
        if self.replay_routing not in ("round_robin", "hash", "affinity"):
            raise ValueError(
                f"replay_routing must be 'round_robin', 'hash' or "
                f"'affinity', got {self.replay_routing!r}")
        if self.telemetry_push_period_s <= 0:
            raise ValueError(
                f"telemetry_push_period_s must be > 0, got "
                f"{self.telemetry_push_period_s}")


class AgentBuilder(abc.ABC):
    """Typed factory bundle from which agents are assembled.

    Subclasses pass their ``BuilderOptions`` to ``super().__init__`` and
    implement the six ``make_*`` factories.  Concrete subclasses are
    recorded in a registry used by the builder-conformance test.
    """

    _registry: List[Type["AgentBuilder"]] = []

    def __init__(self, options: BuilderOptions):
        if not isinstance(options, BuilderOptions):
            raise TypeError(
                f"options must be a BuilderOptions, got {type(options)!r}")
        self._options = options

    def __init_subclass__(cls, **kwargs):
        super().__init_subclass__(**kwargs)
        AgentBuilder._registry.append(cls)

    @property
    def options(self) -> BuilderOptions:
        return self._options

    # ------------------------------------------------------ factory contract
    @abc.abstractmethod
    def make_replay(self):
        """The replay table (or queue) feeding the learner."""

    @abc.abstractmethod
    def make_adder(self, table) -> Optional[Any]:
        """An adder writing actor experience into ``table``; None if the
        builder is offline (fixed dataset, no insertion path)."""

    @abc.abstractmethod
    def make_dataset(self, table) -> Iterator:
        """The learner-facing batch iterator over ``table``."""

    @abc.abstractmethod
    def make_learner(self, iterator, priority_update_cb=None):
        """The learner consuming ``iterator``; ``priority_update_cb`` feeds
        TD-error priorities back to the replay table (may be ignored)."""

    @abc.abstractmethod
    def make_policy(self, evaluation: bool = False):
        """The policy function (behaviour or greedy); None for actors that
        plan rather than evaluate a standalone policy (MCTS)."""

    @abc.abstractmethod
    def make_actor(self, policy, variable_client, adder, seed: int = 0):
        """The actor running ``policy``, pulling weights from
        ``variable_client`` and feeding ``adder`` (which may be None)."""

    def make_batched_actor(self, policy, variable_client, adders,
                           seed: int = 0):
        """A batched actor stepping ``len(adders)`` envs through ONE vmapped
        policy dispatch, fanning transitions out to per-env ``adders``.

        Not abstract: the default vmaps a feed-forward ``(params, key, obs)``
        policy.  Builders with recurrent actors override it to thread
        stacked core state; planning actors (MCTS) override it to raise.
        """
        from repro.core.actors import BatchedFeedForwardActor
        return BatchedFeedForwardActor(policy, variable_client, adders,
                                       rng_seed=seed)

    def make_inference_server(self, variable_source, *, max_batch_size: int,
                              max_wait_ms: float, update_period: int,
                              rng_seed: int = 0):
        """A custom inference service for ``inference="server"`` programs, or
        None to let the execution layer batch ``make_policy`` through the
        generic ``InferenceServer``.

        Not abstract: builders whose serving path is stateful (KV caches,
        recurrent cores) override this to return a server exposing an
        ``INTERFACE`` tuple of RPC method names plus ``stop()``/``stats()``.
        """
        return None

    def make_inference_actor(self, inference, adder=None, adders=None):
        """The actor-side client for an inference service node.

        Not abstract: the default speaks the generic ``InferenceServer``
        protocol (stateless ``select_action`` rows).  Builders overriding
        ``make_inference_server`` override this to match their interface.
        Exactly one of ``adder`` (single env) / ``adders`` (vectorized)
        is given.
        """
        from repro.core.actors import InferenceClientActor
        if adders is not None:
            return InferenceClientActor(inference, adders=adders,
                                        batched=True)
        return InferenceClientActor(inference, adder=adder)


def registered_builders() -> List[Type[AgentBuilder]]:
    """All concrete AgentBuilder subclasses imported so far."""
    return [cls for cls in AgentBuilder._registry
            if not inspect.isabstract(cls)]
