"""Formal builder protocol: the typed contract every agent implements."""
from repro.builders.base import AgentBuilder, BuilderOptions, registered_builders  # noqa: F401
