"""Batched serving driver (the distributed *actor* at scale, SEED-RL style).

Serves a REDUCED variant of any assigned architecture on CPU with batched
requests through the KV/SSM cache — the same ``serve_step`` the dry-run
lowers for decode_32k / long_500k on the production mesh.  Requests are
queued; the server decodes the whole batch lockstep (continuous batching is
approximated by slot recycling: finished requests free their slot).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_arch, reduced
from repro.launch.steps import make_batched_prefill_step, make_serve_step
from repro.models import transformer


class BatchedServer:
    def __init__(self, cfg, batch_slots: int = 4, max_len: int = 128,
                 seed: int = 0, batched_prefill: bool = True):
        self.cfg = cfg
        self.slots = batch_slots
        self.max_len = max_len
        self.params = transformer.init(jax.random.key(seed), cfg, jnp.float32)
        self.cache = transformer.init_cache(cfg, batch_slots, max_len,
                                            jnp.float32)
        self._serve = jax.jit(make_serve_step(cfg))
        # Whole-prompt prefill in one jitted call; dense-family archs only —
        # ssm/hybrid/audio caches still replay the prompt token-at-a-time.
        self._prefill = (jax.jit(make_batched_prefill_step(cfg))
                         if batched_prefill and
                         cfg.arch_type in ("dense", "moe") else None)
        self.pos = 0

    def prefill(self, prompts: np.ndarray):
        """Run the prompt through the cache; returns the first sampled
        token (slots, 1).  One jitted call when the arch supports batched
        prefill, otherwise one ``serve_step`` per prompt token."""
        prompt_len = prompts.shape[1]
        if self._prefill is not None:
            tok, _, self.cache = self._prefill(
                self.params, self.cache, jnp.asarray(prompts))
            return tok
        tok = None
        for t in range(prompt_len):
            tok, _, self.cache = self._serve(
                self.params, self.cache, jnp.asarray(prompts[:, t:t + 1]),
                jnp.int32(t))
        return tok

    def generate(self, prompts: np.ndarray, decode_len: int):
        """prompts: (slots, prompt_len) int32. Lockstep batched decode."""
        prompt_len = prompts.shape[1]
        tok = self.prefill(prompts)
        outs = [np.asarray(tok)]
        for i in range(decode_len - 1):
            tok, logits, self.cache = self._serve(
                self.params, self.cache, tok, jnp.int32(prompt_len + i))
            outs.append(np.asarray(tok))
        return np.concatenate(outs, axis=1)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b", choices=sorted(ARCHS))
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=12)
    p.add_argument("--decode-len", type=int, default=24)
    args = p.parse_args(argv)

    cfg = reduced(get_arch(args.arch))
    server = BatchedServer(cfg, args.slots,
                           args.prompt_len + args.decode_len)
    rng = np.random.RandomState(0)
    done = 0
    t0 = time.time()
    while done < args.requests:
        n = min(args.slots, args.requests - done)
        prompts = rng.randint(0, cfg.vocab_size,
                              (args.slots, args.prompt_len)).astype(np.int32)
        out = server.generate(prompts, args.decode_len)
        done += n
        # recycle: fresh cache per batch (prefix cache reuse is future work)
        server.cache = transformer.init_cache(cfg, args.slots, server.max_len,
                                              jnp.float32)
        print(f"served {done}/{args.requests} "
              f"({done * args.decode_len / (time.time() - t0):.0f} tok/s)")
    tokens = done * args.decode_len
    print(f"total: {tokens} tokens in {time.time()-t0:.2f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
