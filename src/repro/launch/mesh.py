"""Production mesh definitions (TPU v5e pods).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state.  Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) — the leading ``pod``
axis is pure data parallelism across pods (DCN), matching how Acme's learner
would be replicated per pod with gradient all-reduce across pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs (axes exist, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants for the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per chip per direction)
HBM_PER_CHIP = 16 * 1024 ** 3     # 16 GiB
