"""Name-based logical axes for every parameter leaf in the model zoo.

``param_logical_axes(path, shape)`` returns a tuple of logical axis names
(resolved to mesh axes by :class:`repro.sharding.ShardingRules`, which also
handles divisibility fallbacks — e.g. 4 KV heads on a 16-way model axis
degrade to replication rather than failing to lower).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _key_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            names.append(p.name)
    return tuple(names)


def param_logical_axes(path, shape) -> Tuple[Optional[str], ...]:
    names = _key_names(path)
    leaf = names[-1] if names else ""
    parents = set(names[:-1])
    nd = len(shape)

    def pad(axes):
        """Left-pad with stacked-layer axes (scan stacking adds 1-2 dims)."""
        extra = nd - len(axes)
        return tuple(["layers"] * extra) + tuple(axes)

    if leaf == "table":                      # embed / lm_head: (V, d)
        # if vocab doesn't divide the model axis (92553, 51865, ...) the
        # embed_d rule shards d_model instead (axis-dedup keeps it legal).
        return ("vocab", "embed_d")
    if leaf in ("scale", "A_log", "dt_bias", "D", "conv_b", "q_norm", "k_norm"):
        return pad([None] * 1) if nd >= 1 else ()
    if leaf == "wq":
        return pad(("d_model", "heads", "head_dim"))
    if leaf in ("wk", "wv"):
        return pad(("d_model", "kv_heads", "head_dim"))
    if leaf == "wo":
        return pad(("heads", "head_dim", "d_model"))
    if leaf in ("w_gate", "w_up"):
        if "experts" in parents:             # (E, d, f)
            return pad(("experts", "d_model", "expert_ff"))
        return pad(("d_model", "ff"))
    if leaf == "w_down":
        if "experts" in parents:             # (E, f, d)
            return pad(("experts", "expert_ff", "d_model"))
        return pad(("ff", "d_model"))
    if leaf == "router":                     # (d, E) — replicated (tiny)
        return pad(("d_model", None))
    if leaf == "in_proj":                    # (d, packed) — packed dim on model
        return pad(("d_model", "ff"))
    if leaf == "out_proj":                   # (d_inner, d)
        return pad(("ff", "d_model"))
    if leaf == "conv_w":                     # (w, channels)
        return pad((None, "ff"))
    return tuple([None] * nd)


def tree_logical_axes(tree):
    return jax.tree_util.tree_map_with_path(
        lambda path, x: param_logical_axes(path, x.shape), tree)


def tree_pspecs(tree, rules):
    """PartitionSpec pytree for a param(-like) pytree under ``rules``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: rules.mesh_axes(param_logical_axes(path, x.shape), x.shape),
        tree)


def tree_shardings(tree, rules, zero: bool = False):
    """``zero=True`` additionally shards each leaf's first free divisible dim
    over the data(+pod) axes — ZeRO-1 optimizer-state partitioning."""
    from jax.sharding import NamedSharding

    def one(path, x):
        spec = rules.mesh_axes(param_logical_axes(path, x.shape), x.shape)
        if zero and x.ndim:
            spec = rules.zero_spec(spec, x.shape)
        return NamedSharding(rules.mesh, spec)

    return jax.tree_util.tree_map_with_path(one, tree)
