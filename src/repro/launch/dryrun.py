import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) combo.

Proves the distribution config is coherent without hardware: builds the
production mesh (16x16 single-pod / 2x16x16 multi-pod) from placeholder host
devices, jits the train/prefill/serve step with ShapeDtypeStruct inputs, and
records memory_analysis(), cost_analysis(), and the HLO-derived roofline
terms (repro.launch.hlo_analysis) to a JSONL file.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import sharding as shlib
from repro.configs import ARCHS, INPUT_SHAPES, get_arch, get_shape
from repro.launch import hlo_analysis
from repro.launch.mesh import (HBM_BW, HBM_PER_CHIP, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.param_sharding import tree_shardings
from repro.launch.specs import decode_specs, input_specs, params_specs
from repro.launch.steps import (TrainState, make_prefill_step, make_serve_step,
                                make_train_step)
from repro.optim import adam

SKIPS = {
    # (arch, shape): reason — recorded, not silently dropped.
    ("whisper-base", "long_500k"):
        "enc-dec full attention; no sub-quadratic variant in family (DESIGN.md)",
}

# Per-combo production configs required to fit 16 GiB HBM (EXPERIMENTS.md
# §Perf documents the baseline-vs-optimized deltas for each).
COMBO_OVERRIDES = {
    # 7B-class decode with 128 x 32k contexts: f8 KV cache + unrolled layers
    ("codeqwen1.5-7b", "decode_32k"): dict(cache_dtype="f8",
                                           cache_layout="list"),
    ("deepseek-7b", "decode_32k"): dict(cache_dtype="f8",
                                        cache_layout="list"),
    ("internvl2-26b", "decode_32k"): dict(cache_dtype="f8",
                                          cache_layout="list"),
    # MoE with tiny experts: 16 microbatches to bound activation live-set
    ("granite-moe-3b-a800m", "train_4k"): dict(microbatches=16),
}
# dense/moe/vlm archs run long_500k with the sliding-window variant.
SLIDING_WINDOW_FOR_LONG = 8192
FULL_ATTENTION_FAMILIES = ("dense", "moe", "vlm", "audio")


def batch_shardings(rules, specs):
    def spec_for(path, x):
        nd = len(x.shape)
        if nd == 0:
            return rules.named_sharding((), ())
        logical = ["batch"] + [None] * (nd - 1)
        return rules.named_sharding(tuple(logical), x.shape)
    return jax.tree_util.tree_map_with_path(spec_for, specs)


def cache_shardings(rules, cache_specs):
    """Decode caches: (layers, batch, length, kv_heads, head_dim) KV tensors,
    (layers, batch, heads, state, head_dim) SSM states, conv states."""
    def spec_for(path, x):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        leaf = str(names[-1]) if names else ""
        nd = len(x.shape)

        def align(core):
            """Right-align the core logical axes; pad front with 'layers'."""
            if nd <= len(core):
                return core[-nd:]
            return ["layers"] * (nd - len(core)) + core

        if leaf in ("k", "v") or leaf.startswith("cross"):
            log = align(["batch", "kv_seq", "kv_heads", "head_dim"])
        elif leaf == "state":
            log = align(["batch", "ssm_heads", "ssm_state", None])
        elif leaf == "conv":
            log = align(["batch", None, "ff"])
        else:
            log = [None] * nd
        return rules.named_sharding(tuple(log), x.shape)
    return jax.tree_util.tree_map_with_path(spec_for, cache_specs)


def model_flops_analytic(cfg, shape):
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (fwd-only), N = active params."""
    n = cfg.num_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch     # decode: one token per sequence


def build_step_and_args(cfg, shape, rules, objective="bc", remat="full",
                        microbatches=8, cache_dtype=jnp.bfloat16,
                        cache_layout="stacked"):
    pspecs = params_specs(cfg, jnp.bfloat16)
    psh = tree_shardings(pspecs, rules)
    repl = rules.named_sharding((), ())

    if shape.kind == "train":
        opt = adam(1e-4, clip=1.0)
        step_fn = make_train_step(cfg, opt, objective=objective, remat=remat,
                                  microbatches=microbatches)
        state_specs = jax.eval_shape(
            lambda p: TrainState(p, opt.init(p), jnp.zeros((), jnp.int32),
                                 p if objective == "dqn" else None),
            pspecs)
        opt_sh = tree_shardings(state_specs.opt_state, rules, zero=True)  # ZeRO-1
        state_sh = TrainState(psh, opt_sh, repl,
                              psh if objective == "dqn" else None)
        batch = input_specs(cfg, shape)
        bsh = batch_shardings(rules, batch)
        metrics_sh = None
        jitted = jax.jit(step_fn,
                         in_shardings=(state_sh, bsh),
                         out_shardings=(state_sh, metrics_sh),
                         donate_argnums=(0,))
        return jitted, (state_specs, batch)

    if shape.kind == "prefill":
        step_fn = make_prefill_step(cfg)
        batch = input_specs(cfg, shape)
        bsh = batch_shardings(rules, batch)
        jitted = jax.jit(step_fn, in_shardings=(psh, bsh), out_shardings=None)
        return jitted, (pspecs, batch)

    # decode
    step_fn = make_serve_step(cfg)
    d = decode_specs(cfg, shape, cache_dtype=cache_dtype, layout=cache_layout)
    csh = cache_shardings(rules, d["cache"])
    tok_sh = rules.named_sharding(("batch", None), d["token"].shape)
    logits_sh = rules.named_sharding(
        ("batch", "vocab"), (shape.global_batch, cfg.vocab_size))
    jitted = jax.jit(step_fn,
                     in_shardings=(psh, csh, tok_sh, repl),
                     out_shardings=(tok_sh, logits_sh, csh),
                     donate_argnums=(1,))
    return jitted, (pspecs, d["cache"], d["token"], d["pos"])


def run_combo(arch_name, shape_name, mesh_kind, objective="bc", remat="full",
              rules_overrides=None, tag="baseline", microbatches=8,
              cache_dtype=jnp.bfloat16, cache_layout="stacked",
              moe_group=None, moe_cf=None):
    import dataclasses  # noqa: F401 (used below)
    cfg = get_arch(arch_name)
    if cfg.moe is not None and (moe_group or moe_cf):
        moe_updates = {}
        if moe_group:
            moe_updates["group_size"] = moe_group
        if moe_cf:
            moe_updates["capacity_factor"] = moe_cf
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **moe_updates))
    shape = get_shape(shape_name)
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
           "objective": objective if shape.kind == "train" else shape.kind,
           "tag": tag}
    ov = COMBO_OVERRIDES.get((arch_name, shape_name))
    if ov:
        rec["combo_overrides"] = {k: str(v) for k, v in ov.items()}
        if "cache_dtype" in ov:
            cache_dtype = jnp.float8_e4m3fn if ov["cache_dtype"] == "f8" \
                else cache_dtype
        cache_layout = ov.get("cache_layout", cache_layout)
        microbatches = ov.get("microbatches", microbatches)
    if (arch_name, shape_name) in SKIPS:
        rec["status"] = "skipped"
        rec["reason"] = SKIPS[(arch_name, shape_name)]
        return rec
    if shape_name == "long_500k":
        if cfg.arch_type in ("dense", "moe", "vlm"):
            cfg = dataclasses.replace(cfg, sliding_window=SLIDING_WINDOW_FOR_LONG)
            rec["variant"] = f"sliding_window={SLIDING_WINDOW_FOR_LONG}"

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    overrides = dict(rules_overrides or {})
    if shape.kind == "decode" and cfg.num_kv_heads % model_size != 0:
        # KV heads don't divide the model axis: shard the cache's head_dim
        # instead (scores become sharded partial sums over head_dim — XLA
        # inserts the all-reduce; the cache update stays local).
        overrides.setdefault("kv_seq", None)
        overrides.setdefault("head_dim", "model")
        rec["kv_layout"] = "headdim-sharded"
    if cfg.num_heads and cfg.num_heads % model_size != 0:
        # heads don't divide the model axis: sequence-parallel attention
        # (otherwise attention compute replicates onto every chip).
        # Un-chunked seq-par scores are (rows/dev, h, sq/model, sk) f32 —
        # only enable when that buffer stays well under HBM (train is
        # microbatched; prefill only at <=1 row per device).
        data_shards = n_chips // model_size
        rows_per_dev = max(shape.global_batch // data_shards, 1)
        mb = microbatches if shape.kind == "train" else 1
        score_gb = (rows_per_dev / mb) * cfg.num_heads * \
            (shape.seq_len / model_size) * shape.seq_len * 4 / 2 ** 30
        if shape.kind in ("train", "prefill") and score_gb <= 8.0:
            overrides.setdefault("q_seq", "model")
            rec["attn_layout"] = "seq-parallel"
    rules = shlib.ShardingRules(mesh, overrides)
    t0 = time.time()
    try:
        with shlib.use_rules(rules):
            jitted, args = build_step_and_args(cfg, shape, rules,
                                               objective=objective, remat=remat,
                                               microbatches=microbatches,
                                               cache_dtype=cache_dtype,
                                               cache_layout=cache_layout)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        return rec

    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    rec["chips"] = n_chips
    rec["params"] = cfg.num_params()
    rec["active_params"] = cfg.num_active_params()

    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        }
        live = ma.argument_size_in_bytes + ma.temp_size_in_bytes \
            - ma.alias_size_in_bytes + max(
                ma.output_size_in_bytes - ma.alias_size_in_bytes, 0)
        rec["memory"]["approx_live_bytes"] = live
        rec["memory"]["fits_hbm"] = bool(live <= HBM_PER_CHIP)
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        rec["xla_cost"] = {k: float(ca[k]) for k in ("flops", "bytes accessed")
                           if k in ca}
    except Exception as e:  # pragma: no cover
        rec["xla_cost"] = {"error": str(e)}

    hlo = compiled.as_text()
    an = hlo_analysis.analyze(hlo)
    rec["hlo"] = an.as_dict()
    rec["hlo"]["dot_flops"] = an.dot_flops
    rec["hlo"]["conv_flops"] = an.conv_flops

    # --- roofline terms (per chip; module is already per-device) ---
    model_flops = model_flops_analytic(cfg, shape)
    compute_s = an.flops / PEAK_FLOPS_BF16
    memory_s = an.hbm_bytes / HBM_BW
    collective_s = an.collective_bytes / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    rec["roofline"] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_total": model_flops,
        "model_flops_per_chip": model_flops / n_chips,
        "useful_flops_ratio": (model_flops / n_chips) / an.flops if an.flops else 0.0,
    }
    return rec


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--objective", default="bc", choices=["bc", "dqn"])
    p.add_argument("--remat", default="full", choices=["full", "none", "dots"])
    p.add_argument("--tag", default="baseline")
    p.add_argument("--microbatches", type=int, default=8)
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="results/dryrun.jsonl")
    args = p.parse_args(argv)

    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_fail = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mesh_kind in meshes:
                    t0 = time.time()
                    rec = run_combo(arch, shape, mesh_kind,
                                    objective=args.objective,
                                    remat=args.remat, tag=args.tag,
                                    microbatches=args.microbatches)
                    rec["wall_s"] = round(time.time() - t0, 1)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    status = rec["status"]
                    if status == "error":
                        n_fail += 1
                        print(f"[FAIL] {arch} x {shape} x {mesh_kind}: "
                              f"{rec['error']}", file=sys.stderr)
                    else:
                        extra = ""
                        if status == "ok":
                            r = rec["roofline"]
                            extra = (f" dom={r['dominant']}"
                                     f" c={r['compute_s']:.4f}s"
                                     f" m={r['memory_s']:.4f}s"
                                     f" n={r['collective_s']:.4f}s")
                        print(f"[{status}] {arch} x {shape} x {mesh_kind}"
                              f" ({rec['wall_s']}s){extra}")
                        if status == "ok":
                            print("  memory:", rec["memory"])
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
