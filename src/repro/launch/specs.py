"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

``input_specs(cfg, shape)`` returns the exact pytree the corresponding step
function consumes — weak-type-correct, shardable, and allocation-free.
Modality frontends are stubs per the brief: VLM batches carry precomputed
patch embeddings, audio batches carry precomputed frame embeddings.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ArchConfig, InputShape

Specs = Dict[str, Any]


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_specs(cfg: ArchConfig, shape: InputShape,
                act_dtype=jnp.bfloat16) -> Specs:
    b, s = shape.global_batch, shape.seq_len
    text = s - cfg.vision_tokens if cfg.arch_type == "vlm" else s
    batch: Specs = {
        "tokens": sds((b, text), jnp.int32),
        "labels": sds((b, text), jnp.int32),
        "rewards": sds((b, text), jnp.float32),
        "discounts": sds((b, text), jnp.float32),
    }
    if cfg.arch_type == "vlm":
        batch["vision"] = sds((b, cfg.vision_tokens, cfg.d_model), act_dtype)
    if cfg.arch_type == "audio":
        batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), act_dtype)
    return batch


def prefill_specs(cfg: ArchConfig, shape: InputShape,
                  act_dtype=jnp.bfloat16) -> Specs:
    batch = train_specs(cfg, shape, act_dtype)
    return {k: v for k, v in batch.items()
            if k in ("tokens", "vision", "frames")}


def decode_specs(cfg: ArchConfig, shape: InputShape,
                 cache_dtype=jnp.bfloat16, layout: str = "stacked") -> Specs:
    b, s = shape.global_batch, shape.seq_len
    if layout == "list" and cfg.arch_type not in ("dense", "moe", "vlm"):
        layout = "stacked"
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, s, cache_dtype, layout=layout))
    return {
        "cache": cache,
        "token": sds((b, 1), jnp.int32),
        "pos": sds((), jnp.int32),
    }


def params_specs(cfg: ArchConfig, param_dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: transformer.init(jax.random.key(0), cfg, param_dtype))


def input_specs(cfg: ArchConfig, shape: InputShape, **kw) -> Specs:
    if shape.kind == "train":
        return train_specs(cfg, shape, **kw)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape, **kw)
    return decode_specs(cfg, shape, **kw)
