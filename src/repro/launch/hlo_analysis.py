"""Roofline-term extraction from compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, which
under-reports FLOPs/bytes for scanned (layer-stacked) models by ~num_layers x,
and it never reports collective traffic.  This module parses the optimized
HLO module into computations, builds the call graph (while bodies weighted by
their trip count, recovered from the loop-condition constant), and derives:

  * ``flops``            — 2*M*N*K for every dot (+ conv), trip-weighted
  * ``hbm_bytes``        — operand+output bytes of every materialized
                           instruction (fusions counted as one op), i.e. an
                           HBM-traffic model of the fused program
  * ``collective_bytes`` — operand bytes of all-gather / all-reduce /
                           reduce-scatter / all-to-all / collective-permute,
                           trip-weighted, split per kind

All quantities are **per device** (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "u4": 1, "s4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]\w*?)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[a-z]\w*\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_ATTR_COMP_RE = re.compile(r"(condition|body|to_apply|true_computation|false_computation|calls)=%?([\w\.\-]+)")
_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute")
_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "while", "conditional", "call", "after-all",
                 "opt-barrier", "partition-id", "replica-id", "iota"}


def parse_shape_elems(type_str: str) -> List[Tuple[str, int]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dtype, n))
    return out


def shape_bytes(type_str: str) -> int:
    return sum(n * _DTYPE_BYTES[d] for d, n in parse_shape_elems(type_str))


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    out_type: str
    opcode: str
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, str] = field(default_factory=dict)  # name -> out type


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if line.endswith("{") and ("->" in line) and "=" not in line.split("(")[0]:
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                current = Computation(m.group(1))
                comps[current.name] = current
                if line.strip().startswith("ENTRY"):
                    entry_name = current.name
                # parameters from the header signature
                for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\)|[a-z]\w*\[[0-9,]*\]))",
                                      m.group(2)):
                    current.table[pm.group(1)] = pm.group(2)
                continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            name, out_type, opcode = im.group(1), im.group(2), im.group(3)
            # operand names: inside the first parens after opcode
            paren = line.find(opcode) + len(opcode)
            depth = 0
            ops_str = ""
            for ch in line[paren:]:
                if ch == "(":
                    depth += 1
                    if depth == 1:
                        continue
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                if depth >= 1:
                    ops_str += ch
            operands = _OPERAND_RE.findall(ops_str)
            ins = Instr(name, out_type, opcode, line, operands)
            current.instrs.append(ins)
            current.table[name] = out_type
    comps["__entry__"] = comps.get(entry_name, Computation("__missing__"))
    return comps


def _lookup(comps, comp: Computation, name: str) -> str:
    if name in comp.table:
        return comp.table[name]
    for c in comps.values():
        if name in c.table:
            return c.table[name]
    return ""


def _trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        for c in _CONST_RE.findall(ins.line):
            best = max(best, int(c))
    return best


@dataclass
class Analysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(
        default_factory=lambda: defaultdict(lambda: {"count": 0.0, "bytes": 0.0}))
    dot_flops: float = 0.0
    conv_flops: float = 0.0

    def as_dict(self):
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": {k: dict(v) for k, v in self.collectives.items()},
        }


def _analyze_comp(comps, comp: Computation, weight: float, acc: Analysis,
                  seen_stack: Tuple[str, ...]):
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            attrs = dict(_ATTR_COMP_RE.findall(ins.line))
            body, cond = attrs.get("body"), attrs.get("condition")
            trips = _trip_count(comps, cond) if cond else 1
            if body and body in comps and body not in seen_stack:
                _analyze_comp(comps, comps[body], weight * trips, acc,
                              seen_stack + (body,))
            continue
        if op in ("call", "conditional"):
            attrs = dict(_ATTR_COMP_RE.findall(ins.line))
            targets = [v for k, v in attrs.items() if k != "condition"]
            bm = _BRANCHES_RE.search(ins.line)
            if bm:
                targets += [t.strip().lstrip("%") for t in bm.group(1).split(",")]
            for t in targets:
                if t in comps and t not in seen_stack:
                    _analyze_comp(comps, comps[t], weight, acc, seen_stack + (t,))
            continue
        if op in _SKIP_TRAFFIC:
            continue

        if op == "fusion":
            acc.hbm_bytes += weight * _fusion_traffic(comps, comp, ins)
            continue

        out_b = shape_bytes(ins.out_type)
        if op == "dynamic-update-slice":
            # in-place on TPU: traffic = read+write of the update slice only
            upd = shape_bytes(_lookup(comps, comp, ins.operands[1])) \
                if len(ins.operands) > 1 else out_b
            acc.hbm_bytes += weight * 2 * upd
            continue
        if op == "dynamic-slice":
            acc.hbm_bytes += weight * 2 * out_b   # read slice, write slice
            continue
        if op == "gather":
            acc.hbm_bytes += weight * 2 * out_b   # sparse row reads + write
            continue
        if op == "scatter":
            upd = shape_bytes(_lookup(comps, comp, ins.operands[2])) \
                if len(ins.operands) > 2 else out_b
            acc.hbm_bytes += weight * 2 * upd
            continue
        in_b = sum(shape_bytes(_lookup(comps, comp, o)) for o in ins.operands)
        acc.hbm_bytes += weight * (out_b + in_b)

        base = op.replace("-start", "")
        if base in COLLECTIVE_KINDS:
            if op.endswith("-done"):
                continue
            acc.collective_bytes += weight * in_b
            st = acc.collectives[base]
            st["count"] += weight
            st["bytes"] += weight * in_b
            continue

        if op == "dot":
            out_elems = 1
            for d in shape_dims(ins.out_type):
                out_elems *= d
            lhs_type = _lookup(comps, comp, ins.operands[0]) if ins.operands else ""
            lhs_dims = shape_dims(lhs_type)
            cm = _CONTRACT_RE.search(ins.line)
            k = 1
            if cm and lhs_dims:
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        k *= lhs_dims[int(idx)]
            f = 2.0 * out_elems * k
            acc.flops += weight * f
            acc.dot_flops += weight * f
        elif op == "convolution":
            out_elems = 1
            for d in shape_dims(ins.out_type):
                out_elems *= d
            rhs_type = _lookup(comps, comp, ins.operands[1]) if len(ins.operands) > 1 else ""
            rhs_dims = shape_dims(rhs_type)
            k = 1
            for d in rhs_dims[:-1]:   # kernel spatial x in-channel dims
                k *= d
            f = 2.0 * out_elems * k
            acc.flops += weight * f
            acc.conv_flops += weight * f


def _fusion_traffic(comps, comp: Computation, ins: Instr) -> float:
    """Traffic model for a fusion node, faithful to TPU loop fusions:

    * an operand consumed ONLY through dynamic-slice/gather inside the fusion
      contributes the slice/gather sizes, not the full buffer;
    * a fusion whose root is dynamic-update-slice writes only the update
      (XLA in-place aliases the big operand on TPU);
    * everything else: full operand reads + output write.
    """
    attrs = dict(_ATTR_COMP_RE.findall(ins.line))
    fused = comps.get(attrs.get("calls", ""))
    out_b = shape_bytes(ins.out_type)
    if fused is None or not fused.instrs:
        in_b = sum(shape_bytes(_lookup(comps, comp, o)) for o in ins.operands)
        return out_b + in_b

    # in-place accumulator pattern: the fusion rewrites a big buffer through a
    # dynamic-update-slice and returns a buffer of identical type (TPU aliases
    # it in place).  Traffic = the *other* operands (the update data), twice.
    has_dus = any(fi.opcode == "dynamic-update-slice" for fi in fused.instrs)
    if has_dus:
        op_bytes = [shape_bytes(_lookup(comps, comp, o)) for o in ins.operands]
        if any(b == out_b for b in op_bytes):
            small = sum(b for b in op_bytes if b != out_b)
            return 2.0 * small

    # map parameter index -> instruction name inside the fused computation
    param_names = {}
    for fi in fused.instrs:
        if fi.opcode == "parameter":
            m = _PARAM_IDX_RE.search(fi.line)
            if m:
                param_names[int(m.group(1))] = fi.name

    total = 0.0
    for i, operand in enumerate(ins.operands):
        full = shape_bytes(_lookup(comps, comp, operand))
        pname = param_names.get(i)
        if pname is None:
            total += full
            continue
        users = [fi for fi in fused.instrs if pname in fi.operands]
        slicing = [fi for fi in users
                   if fi.opcode in ("dynamic-slice", "gather")]
        dus_target = [fi for fi in users
                      if fi.opcode == "dynamic-update-slice"
                      and fi.operands and fi.operands[0] == pname]
        if users and len(slicing) + len(dus_target) == len(users):
            total += sum(shape_bytes(fi.out_type) for fi in slicing)
            # dus writes counted on the output side
        else:
            total += full

    root = fused.instrs[-1]
    if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
        upd = shape_bytes(fused.table.get(root.operands[1], ""))
        total += 2 * (upd or out_b)
    else:
        total += out_b
    return total


def analyze(text: str) -> Analysis:
    comps = parse_module(text)
    acc = Analysis()
    entry = comps["__entry__"]
    _analyze_comp(comps, entry, 1.0, acc, (entry.name,))
    return acc


def collective_stats(text: str) -> Dict[str, Dict[str, float]]:
    return {k: dict(v) for k, v in analyze(text).collectives.items()}


def total_collective_bytes(text: str) -> float:
    return analyze(text).collective_bytes
