import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver: run tagged dry-run variants of a combo and log
hypothesis -> change -> before/after roofline terms to JSONL.

Usage:
  PYTHONPATH=src python -m repro.launch.perf --pair qwen3-1.7b:train_4k \
      --variant remat=dots --tag H1-dots
"""
import argparse
import json
import sys
import time

import jax.numpy as jnp

from repro.launch.dryrun import run_combo

DTYPES = {"bf16": jnp.bfloat16, "f8": jnp.float8_e4m3fn, "f32": jnp.float32}


def parse_variant(tokens):
    kw = {}
    rules = {}
    for tok in tokens or []:
        k, v = tok.split("=", 1)
        if k == "remat":
            kw["remat"] = v
        elif k == "microbatches":
            kw["microbatches"] = int(v)
        elif k == "cache_dtype":
            kw["cache_dtype"] = DTYPES[v]
        elif k == "cache_layout":
            kw["cache_layout"] = v
        elif k == "moe_group":
            kw["moe_group"] = int(v)
        elif k == "moe_cf":
            kw["moe_cf"] = float(v)
        elif k == "objective":
            kw["objective"] = v
        elif k.startswith("rule."):
            rules[k[5:]] = None if v in ("none", "None") else v
        else:
            raise ValueError(tok)
    if rules:
        kw["rules_overrides"] = rules
    return kw


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--pair", required=True, help="arch:shape")
    p.add_argument("--mesh", default="single")
    p.add_argument("--variant", nargs="*", default=[])
    p.add_argument("--tag", required=True)
    p.add_argument("--out", default="results/perf_iterations.jsonl")
    args = p.parse_args(argv)

    arch, shape = args.pair.split(":")
    kw = parse_variant(args.variant)
    t0 = time.time()
    rec = run_combo(arch, shape, args.mesh, tag=args.tag, **kw)
    rec["variant_args"] = args.variant
    rec["wall_s"] = round(time.time() - t0, 1)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    if rec["status"] == "ok":
        r = rec["roofline"]
        m = rec["memory"]
        print(f"[{args.tag}] {arch} x {shape}: dom={r['dominant']}"
              f" c={r['compute_s']:.4f} m={r['memory_s']:.4f}"
              f" n={r['collective_s']:.4f} useful={r['useful_flops_ratio']:.2f}"
              f" live={m['approx_live_bytes']/2**30:.1f}GB fits={m['fits_hbm']}")
    else:
        print(f"[{args.tag}] {rec['status']}: {rec.get('error','')[:200]}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
