"""Step functions the launcher jits onto the mesh.

``train_step`` is the Acme *learner* update (default objective: behaviour
cloning / offline next-token CE, §3.7 of the paper; ``dqn`` gives the
double-DQN TD objective of §3.2 with the LM head as Q-values).
``prefill_step`` scores a full sequence (actor-side batched inference),
``serve_step`` decodes one token against a KV/SSM cache (the distributed
actor's ``select_action`` hot path, SEED-RL style).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers, transformer
from repro.models.config import ArchConfig
from repro.optim import Optimizer, apply_updates


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array
    target_params: Any = None   # dqn objective only


def init_train_state(rng, cfg: ArchConfig, opt: Optimizer, *,
                     param_dtype=jnp.float32, objective="bc") -> TrainState:
    params = transformer.init(rng, cfg, param_dtype)
    target = params if objective == "dqn" else None
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32), target)


def _bc_loss(params, cfg, batch, remat):
    feats, aux = transformer.forward_features(params, cfg, batch, remat=remat)
    from repro.sharding import shard
    feats = shard(feats, "batch", None, "d_model")   # gather seq for the CE scan
    table = transformer.unembed_table(params, cfg)
    loss = layers.chunked_cross_entropy(feats[:, :-1], table,
                                        batch["labels"][:, 1:],
                                        valid_vocab=cfg.vocab_size)
    metrics = {"ce": loss}
    for k, v in aux.items():
        loss = loss + v
        metrics[k] = v
    metrics["loss"] = loss
    return loss, metrics


def _dqn_loss(params, target_params, cfg, batch, remat):
    """Double-DQN 1-step TD over the token MDP; logits = Q(o_t, .)."""
    q, aux = transformer.forward(params, cfg, batch, remat=remat)
    q_target, _ = transformer.forward(target_params, cfg, batch, remat=remat)
    q, q_target = q.astype(jnp.float32), q_target.astype(jnp.float32)
    a_star = jnp.argmax(q[:, 1:], axis=-1)                       # online argmax
    next_v = jnp.take_along_axis(q_target[:, 1:], a_star[..., None], -1)[..., 0]
    y = batch["rewards"][:, :-1] + batch["discounts"][:, :-1] * \
        jax.lax.stop_gradient(next_v)
    q_taken = jnp.take_along_axis(q[:, :-1], batch["labels"][:, 1:][..., None],
                                  -1)[..., 0]
    loss = 0.5 * jnp.mean(jnp.square(y - q_taken))
    for v in aux.values():
        loss = loss + v
    return loss, {"loss": loss, "td": loss}


def make_train_step(cfg: ArchConfig, opt: Optimizer, *, objective="bc",
                    remat="full", target_period: int = 100,
                    microbatches: int = 1):
    """``microbatches > 1`` = gradient accumulation: the global batch is split
    along axis 0 and scanned, dividing activation live-memory by M while
    keeping the update mathematically identical (mean of microbatch grads)."""

    def grad_fn(params, target_params, batch):
        if objective == "bc":
            return jax.grad(_bc_loss, has_aux=True)(params, cfg, batch, remat)
        elif objective == "dqn":
            return jax.grad(_dqn_loss, has_aux=True)(
                params, target_params, cfg, batch, remat)
        raise ValueError(objective)

    def accumulate(params, target_params, batch):
        if microbatches == 1:
            return grad_fn(params, target_params, batch)
        def split(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(acc, mbatch):
            g, m = grad_fn(params, target_params, mbatch)
            acc_g, acc_m = acc
            acc_g = jax.tree.map(lambda a, x: a + x.astype(jnp.float32) / microbatches,
                                 acc_g, g)
            acc_m = jax.tree.map(lambda a, x: a + x / microbatches, acc_m, m)
            return (acc_g, acc_m), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        g0, m0 = jax.eval_shape(lambda: grad_fn(
            params, target_params, jax.tree.map(lambda x: x[0], mb)))
        zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
        (grads, metrics), _ = jax.lax.scan(body, (zero_g, zero_m), mb)
        return grads, metrics

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        grads, metrics = accumulate(state.params, state.target_params, batch)
        if objective == "dqn":
            from repro.optim import periodic_update
            target = periodic_update(state.params, state.target_params,
                                     state.step, target_period)
        else:
            target = state.target_params
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        new_state = TrainState(params, opt_state, state.step + 1, target)
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, remat="none", chunk: int = 1024):
    """Actor-side batched scoring: greedy actions per position + last-position
    logits, computed over seq chunks so full (b, s, V) logits never live."""

    def prefill_step(params, batch):
        feats, _ = transformer.forward_features(params, cfg, batch, remat=remat)
        table = transformer.unembed_table(params, cfg)
        b, s, d = feats.shape
        c = chunk if s % chunk == 0 else s
        n = s // c

        def body(_, xc):
            logits = transformer.mask_pad_logits(
                layers.unembed(table, xc), cfg)
            return None, jnp.argmax(logits, axis=-1).astype(jnp.int32)

        xs = jnp.moveaxis(feats.reshape(b, n, c, d), 1, 0)
        _, acts = jax.lax.scan(body, None, xs)
        actions = jnp.moveaxis(acts, 0, 1).reshape(b, s)
        last_logits = transformer.mask_pad_logits(
            layers.unembed(table, feats[:, -1]), cfg)
        return {"actions": actions, "last_logits": last_logits}

    return prefill_step


def make_serve_step(cfg: ArchConfig, *, backend: str = "jnp"):
    """One-token incremental decode against the cache.  ``backend`` selects
    the decode-attention path on dense archs: ``"jnp"`` (pure XLA),
    ``"kernel"`` (pallas ``decode_attention``), ``"ref"`` (the kernels/ref.py
    oracle), or ``"auto"`` (kernel on TPU, ref elsewhere)."""

    def serve_step(params, cache, token, pos):
        logits, cache = transformer.decode_step(params, cfg, cache, token, pos,
                                                backend=backend)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, logits, cache
    return serve_step


def make_batched_prefill_step(cfg: ArchConfig):
    """Whole-prompt prefill THROUGH the decode cache in one jitted call —
    the batched replacement for stepping ``serve_step`` once per prompt
    token.  Dense-family archs with the stacked ``"kv"`` cache layout only
    (``transformer.prefill`` raises otherwise).

    Returns ``prefill_step(params, cache, tokens, lengths=None) ->
    (next_token (b, 1) int32, logits (b, V), cache)`` where ``tokens`` is
    right-padded (b, s) and ``lengths`` masks the padding; decode then
    continues at position ``lengths[i]`` (or ``s``)."""

    def prefill_step(params, cache, tokens, lengths=None):
        logits, cache = transformer.prefill(params, cfg, cache, tokens,
                                            lengths=lengths)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, logits, cache
    return prefill_step
