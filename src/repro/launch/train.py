"""End-to-end large-model training driver (the Acme *learner* at scale).

On CPU this trains a REDUCED variant of any assigned architecture on the
synthetic token-MDP corpus (behaviour-cloning / offline-RL objective) for a
few hundred steps — the same ``train_step`` the multi-pod dry-run lowers for
the production mesh.  On a real TPU fleet the only changes are
``--mesh single|multi`` (instead of host) and the data source.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shlib
from repro.checkpoint import Checkpointer
from repro.configs import ARCHS, get_arch, reduced
from repro.envs import TokenChain
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import init_train_state, make_train_step
from repro.optim import adam, cosine_schedule


def make_corpus_sampler(vocab: int, seq: int, batch: int, seed: int = 0):
    """Batches of token-MDP trajectories (observations=contexts, actions
    become next-token labels) — the offline dataset for the BC learner."""
    env = TokenChain(vocab_size=vocab, episode_len=seq + 1, seed=seed)
    rng = np.random.RandomState(seed)

    def sample():
        toks = np.zeros((batch, seq + 1), np.int32)
        for b in range(batch):
            ts = env.reset()
            # roll the chain; the "expert" emits the true next token
            for t in range(seq + 1):
                target = env._next_token()
                toks[b, t] = target
                ts = env.step(target)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, :-1]),
            "rewards": jnp.ones((batch, seq), jnp.float32),
            "discounts": jnp.ones((batch, seq), jnp.float32),
        }

    return sample


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b", choices=sorted(ARCHS))
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--objective", default="bc", choices=["bc", "dqn"])
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--full-size", action="store_true",
                   help="use the full config (requires the production mesh)")
    args = p.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    mesh = make_host_mesh()
    rules = shlib.ShardingRules(mesh)

    opt = adam(cosine_schedule(args.lr, args.steps, warmup_steps=10), clip=1.0)
    with shlib.use_rules(rules):
        state = init_train_state(jax.random.key(0), cfg, opt,
                                 param_dtype=jnp.float32,
                                 objective=args.objective)
        step_fn = jax.jit(make_train_step(cfg, opt, objective=args.objective,
                                          remat="none", microbatches=1))
        sampler = make_corpus_sampler(cfg.vocab_size, args.seq, args.batch)

        ck = Checkpointer(args.checkpoint_dir) if args.checkpoint_dir else None
        t0 = time.time()
        ce0 = None
        for i in range(args.steps):
            batch = sampler()
            state, metrics = step_fn(state, batch)
            if i == 0:
                ce0 = float(metrics["ce"])
            if (i + 1) % 20 == 0:
                print(f"step {i+1:4d}  ce {float(metrics['ce']):.4f}  "
                      f"({(i+1)/(time.time()-t0):.2f} steps/s)", flush=True)
                if ck:
                    ck.save(state, step=i + 1,
                            metadata={"walltime": time.time() - t0})
        ce1 = float(metrics["ce"])
        print(f"done: ce {ce0:.4f} -> {ce1:.4f} "
              f"({'improved' if ce1 < ce0 else 'NO IMPROVEMENT'})")
        return 0 if ce1 < ce0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
