"""Flash attention (forward) as a Pallas TPU kernel.

TPU-native adaptation: the grid is (batch, heads, q_blocks, kv_blocks) with
the kv dimension marked "arbitrary" (sequential) so the online-softmax
accumulators (m, l, acc) live in VMEM scratch across kv steps.  Block shapes
are MXU-aligned (multiples of 128 on the matmul dims); causal/sliding-window
masking is applied with 2-D iotas inside the kernel.

This is the TARGET kernel for TPU; on this CPU container it is validated with
``interpret=True`` against :func:`repro.kernels.ref.flash_attention_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept either.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, bq, bk, nk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                             # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                          # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0, ...] = (acc_ref[...] /
                            jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (b, h, sq, d); k, v: (b, h, sk, d) -> (b, h, sq, d)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = scale if scale is not None else d ** -0.5

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    grid = (b, h, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, q_, k_: (b_, h_, k_, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, q_, k_: (b_, h_, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
