"""Single-token decode attention (flash-decoding) as a Pallas TPU kernel.

The serve-path hot spot: one query head-block against a long KV cache.
Grid: (batch, heads, kv_blocks); the kv dimension is sequential with the
online-softmax partials (m, l, acc) in VMEM scratch, so the cache is streamed
HBM->VMEM exactly once.  Variable-length caches are handled with a per-batch
``lengths`` vector masking the tail block.

Validated with ``interpret=True`` against ``ref.decode_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept either.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale, bk, nk):
    ki = pl.program_id(2)
    b = pl.program_id(0)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (1, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bk, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)        # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (1, bk)

    length = len_ref[b]
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < length, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30))[0].astype(o_ref.dtype)


def decode_attention(q, k, v, lengths, *, block_k: int = 512,
                     interpret: bool = False):
    """q: (b, h, d); k, v: (b, s, h, d) MHA layout; lengths: (b,) int32."""
    b, h, d = q.shape
    s = k.shape[1]
    bk = min(block_k, s)
    assert s % bk == 0, (s, bk)
    nk = s // bk
    kernel = functools.partial(_decode_kernel, scale=d ** -0.5, bk=bk, nk=nk)
    # layout: q (b, h, 1, d) blocks; k/v (b, s, h, d) -> block (1, bk, 1, d)
    q4 = q[:, :, None, :]
    return pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec(lengths.shape, lambda b_, h_, k_: (0,)),
            pl.BlockSpec((1, 1, 1, d), lambda b_, h_, k_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h_, k_: (b_, k_, h_, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h_, k_: (b_, k_, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b_, h_, k_: (b_, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q4, k, v)
