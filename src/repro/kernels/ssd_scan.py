"""Mamba2 SSD chunk scan as a Pallas TPU kernel.

Grid: (batch, heads, chunks); the chunk dimension is sequential with the
inter-chunk SSM state (n, p) carried in VMEM scratch — the TPU-native
equivalent of Mamba2's fused CUDA chunk-scan: all heavy ops inside a chunk
are (chunk x chunk) / (chunk x n) matmuls that map to the MXU, and the
recurrence across chunks is a scalar-decay state update done once per grid
step instead of a per-token scan.

Validated with ``interpret=True`` against ``ref.ssd_scan_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; accept either.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk, n, p):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # (chunk, p)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (chunk, 1) -> squeeze
    dt = dt[:, 0]
    a = a_ref[0]                               # scalar decay rate (f32)
    B = b_ref[0].astype(jnp.float32)           # (chunk, n)
    C = c_ref[0].astype(jnp.float32)           # (chunk, n)

    dA = dt * a                                # (chunk,) log decays
    cum = jnp.cumsum(dA)                       # within-chunk cumulative

    # intra-chunk: L[i, j] = exp(cum_i - cum_j) for j <= i
    seg = cum[:, None] - cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(qi >= kj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # (chunk, chunk)
    M = scores * L * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())))        # (chunk, p)

    # inter-chunk: contribution of the carried state
    decay_from_start = jnp.exp(cum)                                # (chunk,)
    y += (jax.lax.dot_general(C, state_ref[...], (((1,), (0,)), ((), ())))
          * decay_from_start[:, None])

    # state update: h <- h * exp(sum dA) + sum_j B_j dt_j decay_to_end_j x_j
    decay_to_end = jnp.exp(cum[-1] - cum)                          # (chunk,)
    weighted_B = B * (dt * decay_to_end)[:, None]                  # (chunk, n)
    new_state = jax.lax.dot_general(weighted_B, x, (((0,), (0,)), ((), ())))
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1]) + new_state

    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, interpret: bool = False):
    """x: (b, s, h, p); dt: (b, s, h) (>0); A: (h,) negative;
    B, C: (b, s, n) (single group). Returns y: (b, s, h, p) float32."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    # rearrange to head-major blocks: x (b, h, s, p), dt (b, h, s, 1)
    xh = jnp.moveaxis(x, 2, 1)
    dth = jnp.moveaxis(dt, 2, 1)[..., None]
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n=n, p=p)
    yh = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xh, dth, A.astype(jnp.float32), B, C)
    return jnp.moveaxis(yh, 1, 2)
