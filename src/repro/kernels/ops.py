"""Jitted public wrappers around the Pallas kernels.

``interpret`` defaults to True when no TPU is present (this container), so
the same call sites run the kernel bodies on CPU for correctness and compile
the real Mosaic kernels on TPU.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.ssd_scan import ssd_scan as _ssd
from repro.kernels.vtrace_kernel import vtrace as _vtrace


@functools.cache
def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, lengths, *, block_k=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _decode(q, k, v, lengths, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd(x, dt, A, B, C, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("clip_rho", "clip_c", "block_b",
                                             "interpret"))
def vtrace(values, next_values, rewards, discounts, rhos, *, clip_rho=1.0,
           clip_c=1.0, block_b=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _vtrace(values, next_values, rewards, discounts, rhos,
                   clip_rho=clip_rho, clip_c=clip_c, block_b=block_b,
                   interpret=interpret)
