"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three artifacts (per the repo convention):
  <name>.py  — pl.pallas_call + BlockSpec implementation (TPU target)
  ops.py     — jitted public wrappers (interpret=True off-TPU)
  ref.py     — pure-jnp oracles used by the allclose test sweeps
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import decode_attention, flash_attention, ssd_scan, vtrace  # noqa: F401
