"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (b, h, sq, d); k, v: (b, h, sk, d). Plain softmax attention."""
    sq, sk = q.shape[2], k.shape[2]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(sq)[:, None]
        ki = jnp.arange(sk)[None, :]
        mask = qi >= ki
        if window is not None:
            mask &= (qi - ki) < window
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths):
    """q: (b, h, d); k, v: (b, s, h, d); lengths: (b,) valid prefix lengths."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = jnp.arange(k.shape[1])[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C, chunk):
    """Chunked SSD — delegates to the model's pure-jnp implementation.

    x: (b, s, h, p); dt: (b, s, h); A: (h,); B, C: (b, s, n).
    Returns (y (b, s, h, p) float32, final_state (b, h, n, p) float32).
    """
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, B, C, chunk)


def vtrace_ref(values, next_values, rewards, discounts, rhos,
               clip_rho: float = 1.0, clip_c: float = 1.0):
    """V-trace targets (Espeholt et al. 2018), time-major (T, B) inputs.

    vs_t = V_t + delta_t + gamma_t * c_t * (vs_{t+1} - V_{t+1}),
    delta_t = clipped_rho_t * (r_t + gamma_t * V_{t+1} - V_t).
    """
    rho_c = jnp.minimum(rhos, clip_rho)
    cs = jnp.minimum(rhos, clip_c)
    deltas = rho_c * (rewards + discounts * next_values - values)

    def body(acc, inp):
        delta, disc, c, nv = inp
        acc = delta + disc * c * acc
        return acc, acc

    T = values.shape[0]
    _, diffs = jax.lax.scan(
        body, jnp.zeros_like(values[0]),
        (deltas, discounts, cs, next_values), reverse=True)
    vs = values + diffs
    # policy-gradient advantages use vs_{t+1}
    vs_next = jnp.concatenate([vs[1:], next_values[-1:]], axis=0)
    pg_adv = rho_c * (rewards + discounts * vs_next - values)
    return vs, pg_adv
