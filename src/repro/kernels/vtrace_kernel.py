"""V-trace (IMPALA off-policy correction) as a Pallas TPU kernel.

The RL-specific sequence hot spot: the backward recurrence
``vs_t - V_t = delta_t + gamma_t c_t (vs_{t+1} - V_{t+1})`` over long
learner sequences.  Grid: (batch_blocks,) — each grid step loads a
(T, block_b) tile into VMEM, runs the reverse recurrence with a
``fori_loop`` over T entirely in VMEM, and writes both the targets and the
policy-gradient advantages.  On TPU this turns a memory-bound per-step scan
into a single VMEM-resident pass.

Validated with ``interpret=True`` against ``ref.vtrace_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _vtrace_kernel(v_ref, nv_ref, r_ref, g_ref, rho_ref, vs_ref, adv_ref,
                   acc_ref, *, T, clip_rho, clip_c):
    values = v_ref[...].astype(jnp.float32)       # (T, bb)
    next_values = nv_ref[...].astype(jnp.float32)
    rewards = r_ref[...].astype(jnp.float32)
    discounts = g_ref[...].astype(jnp.float32)
    rhos = rho_ref[...].astype(jnp.float32)

    rho_c = jnp.minimum(rhos, clip_rho)
    cs = jnp.minimum(rhos, clip_c)
    deltas = rho_c * (rewards + discounts * next_values - values)

    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(i, _):
        t = T - 1 - i
        acc = acc_ref[0]
        new = deltas[t] + discounts[t] * cs[t] * acc
        vs_ref[t, :] = (values[t] + new).astype(vs_ref.dtype)
        acc_ref[0] = new
        return ()

    jax.lax.fori_loop(0, T, body, ())

    vs = vs_ref[...].astype(jnp.float32)
    vs_next = jnp.concatenate([vs[1:], next_values[-1:]], axis=0)
    adv_ref[...] = (rho_c * (rewards + discounts * vs_next - values)
                    ).astype(adv_ref.dtype)


def vtrace(values, next_values, rewards, discounts, rhos, *,
           clip_rho: float = 1.0, clip_c: float = 1.0,
           block_b: int = 128, interpret: bool = False):
    """All inputs time-major (T, B) float32. Returns (vs, pg_advantages)."""
    T, Bt = values.shape
    bb = min(block_b, Bt)
    assert Bt % bb == 0
    kernel = functools.partial(_vtrace_kernel, T=T, clip_rho=clip_rho,
                               clip_c=clip_c)
    spec = pl.BlockSpec((T, bb), lambda b_: (0, b_))
    vs, adv = pl.pallas_call(
        kernel,
        grid=(Bt // bb,),
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((T, Bt), jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((1, bb), jnp.float32)],
        interpret=interpret,
    )(values, next_values, rewards, discounts, rhos)
    return vs, adv
