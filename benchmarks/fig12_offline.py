"""Fig 12/13: offline RL — BC and value-based learners on fixed datasets.

Claim: given data from a converged ("data generation") policy, offline
learners approach that policy's performance without any environment
interaction during training; value-based offline learners (here offline DQN
with double-Q, per Fig 13) match BC or better on the same data."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core import EnvironmentLoop, FeedForwardActor, VariableClient, make_environment_spec
from repro.envs import Catch
from repro.replay import dataset_from_list


def _generation_policy(board):
    ball = int(np.argmax(board[:-1].max(axis=0)))
    paddle = int(np.argmax(board[-1]))
    return int(1 + np.sign(ball - paddle))


def _collect_dataset(num_episodes=150, quality=0.9, seed=0):
    """Mixture of expert + random actions (includes low-quality data, as the
    paper's datasets do)."""
    from repro.adders import NStepTransitionAdder
    from repro.replay import MinSize, Table, Uniform
    env = Catch(seed=seed)
    rng = np.random.RandomState(seed)
    table = Table("data", 1_000_000, Uniform(0), MinSize(1))
    adder = NStepTransitionAdder(table, 1, 0.99)
    gen_returns = []
    for _ in range(num_episodes):
        ts = env.reset()
        adder.add_first(ts)
        total = 0.0
        while not ts.last():
            if rng.rand() < quality:
                a = _generation_policy(ts.observation)
            else:
                a = int(rng.randint(3))
            ts = env.step(a)
            adder.add(a, ts)
            total += ts.reward
        gen_returns.append(total)
    items = [table._items[k].data for k in table._order]
    return items, float(np.mean(gen_returns))


def _evaluate(learner, policy, episodes=25, seed=123):
    actor = FeedForwardActor(policy, VariableClient(learner))
    loop = EnvironmentLoop(Catch(seed=seed), actor)
    return float(np.mean([loop.run_episode()["episode_return"]
                          for _ in range(episodes)]))


def main(learner_steps: int = 400):
    import jax
    spec = make_environment_spec(Catch(seed=0))
    items, gen_return = _collect_dataset()
    csv_row("fig12/data_generation_return", round(gen_return, 3),
            "dashed line in Fig 12/13")

    # BC
    from repro.agents import bc as bc_lib
    cfg = bc_lib.BCConfig()
    learner = bc_lib.make_learner(spec, cfg,
                                  dataset_from_list(items, 64), jax.random.key(0))
    for _ in range(learner_steps):
        learner.step()
    bc_return = _evaluate(learner, bc_lib.make_eval_policy(spec, cfg))
    csv_row("fig12/bc_return", round(bc_return, 3))

    # offline DQN (double-Q + Adam, Fig 13 recipe)
    from repro.agents import dqn as dqn_lib
    qcfg = dqn_lib.DQNConfig(prioritized=False)
    qlearner = dqn_lib.make_learner(spec, qcfg,
                                    dataset_from_list(items, 64),
                                    jax.random.key(1))
    for _ in range(learner_steps):
        qlearner.step()
    dqn_return = _evaluate(qlearner, dqn_lib.make_eval_policy(spec, qcfg))
    csv_row("fig13/offline_dqn_return", round(dqn_return, 3))

    csv_row("fig12/offline_matches_generator",
            int(bc_return > gen_return - 0.35 or dqn_return > gen_return - 0.35),
            "offline learner approaches the data-generation policy")
    return gen_return, bc_return, dqn_return


if __name__ == "__main__":
    main()
