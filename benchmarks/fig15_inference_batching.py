"""Fig 15: batched acting — actor steps/sec vs ``num_envs`` x inference mode.

Two claims behind the batched acting pipeline:

1. **Vectorized env loops** (tier 1): N Catch envs stepped by ONE vmapped,
   jitted policy dispatch per tick beat N sequential single-env loops (one
   dispatch per step each) — the per-step Python/JAX dispatch overhead is
   amortized across the batch.  Acceptance: >= 3x actor steps/sec at 16
   vectorized envs vs 16 sequential single-env loops on the same policy.

2. **The InferenceServer** (tier 2, SEED-style): with multiprocess actors
   doing REMOTE inference, coalescing ``select_action`` RPCs into batched
   forward passes beats per-actor remote dispatch (the same server with the
   coalescing window disabled: one forward pass per request) — acceptance
   at >= 4 actor workers.  The figure also reports ``inference="local"``
   (each actor owns a policy copy) for context: on few-core CPU hosts with
   a small MLP the local copy wins outright — centralizing inference pays
   off once the policy is expensive enough (or lives on an accelerator the
   actors don't have), which is SEED's premise.

    python benchmarks/fig15_inference_batching.py            # full sweep
    python benchmarks/fig15_inference_batching.py --smoke    # CI mechanics
"""
from __future__ import annotations

import sys
import time

from benchmarks.common import csv_row
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.core import (Counter, EnvironmentLoop, VariableClient,
                        VectorizedEnvironmentLoop, make_environment_spec)
from repro.envs import Catch, VectorEnv
from repro.experiments import ExperimentConfig, run_distributed_experiment

ENV_COUNTS = (1, 4, 16)
STEPS_PER_ENV = 2000
SMOKE_STEPS_PER_ENV = 50

SERVER_ACTORS = 4
SERVER_TARGET_STEPS = 4000
SMOKE_SERVER_TARGET_STEPS = 200
# Policy torso wide enough that a forward pass dominates the courier hop —
# the regime the inference server exists for (SEED's premise).
SERVER_HIDDEN = 256
TIMEOUT_S = 240.0


# Module-level factories: the multiprocess backend pickles them into
# spawned actor processes.
def builder_factory(spec):
    # samples_per_insert=0 -> MinSize limiter: actors run unthrottled, so
    # the figure measures interaction throughput, not the SPI schedule.
    return DQNBuilder(spec, DQNConfig(hidden=SERVER_HIDDEN,
                                      min_replay_size=100,
                                      samples_per_insert=0.0,
                                      batch_size=16, n_step=1), seed=0)


def env_factory(seed):
    return Catch(seed=seed)


# ------------------------------------------------- tier 1: vectorized loops
def _acting_builder():
    spec = make_environment_spec(Catch(seed=0))
    return DQNBuilder(spec, DQNConfig(min_replay_size=100,
                                      samples_per_insert=0.0,
                                      batch_size=16, n_step=1), seed=0)


def run_sequential(num_envs: int, steps_per_env: int) -> float:
    """N single-env loops sharing one actor (one policy dispatch PER STEP),
    run one after another — the pre-vectorization acting path."""
    builder = _acting_builder()
    learner = builder.make_learner(iter([]))
    actor = builder.make_actor(builder.make_policy(evaluation=False),
                               VariableClient(learner), adder=None, seed=0)
    loops = [EnvironmentLoop(Catch(seed=i), actor, counter=Counter(),
                             should_update=False) for i in range(num_envs)]
    loops[0].run(num_steps=9)   # compile outside the timed window
    t0 = time.perf_counter()
    for loop in loops:
        loop.run(num_steps=steps_per_env)
    wall = time.perf_counter() - t0
    return num_envs * steps_per_env / wall


def run_vectorized(num_envs: int, steps_per_env: int) -> float:
    """One VectorEnv + batched actor: one policy dispatch per N steps."""
    builder = _acting_builder()
    learner = builder.make_learner(iter([]))
    actor = builder.make_batched_actor(
        builder.make_policy(evaluation=False),
        VariableClient(learner), [None] * num_envs, seed=0)
    loop = VectorizedEnvironmentLoop(
        VectorEnv(env_factory, num_envs, seed=0), actor, counter=Counter(),
        should_update=False)
    loop.run(num_steps=9 * num_envs)   # compile outside the timed window
    t0 = time.perf_counter()
    loop.run(num_steps=num_envs * steps_per_env)
    wall = time.perf_counter() - t0
    return num_envs * steps_per_env / wall


# --------------------------------------------- tier 2: inference placement
def run_inference_mode(mode: str, num_actors: int, target_steps: int):
    """mode: 'local' (per-actor policy copy), 'server' (coalescing window),
    'server-nobatch' (same server, window disabled: ONE request per forward
    pass — every remote actor pays a full model dispatch each)."""
    config = ExperimentConfig(
        builder_factory=builder_factory, environment_factory=env_factory,
        seed=0, eval_episodes=0, launcher="multiprocess",
        inference="server" if mode.startswith("server") else "local",
        inference_max_batch_size=1 if mode == "server-nobatch" else None)
    result = run_distributed_experiment(
        config, num_actors=num_actors, max_actor_steps=target_steps,
        timeout_s=TIMEOUT_S)
    steps = int(result.counts.get("actor_steps", 0))
    wall = result.extras["walltime"]
    return {"steps": steps, "wall": wall,
            "steps_per_sec": steps / max(wall, 1e-9),
            "inference": result.extras.get("inference")}


def main(smoke: bool = False):
    steps_per_env = SMOKE_STEPS_PER_ENV if smoke else STEPS_PER_ENV
    env_counts = (4,) if smoke else ENV_COUNTS
    results = {}

    for n in env_counts:
        seq = run_sequential(n, steps_per_env)
        vec = run_vectorized(n, steps_per_env)
        results[n] = (seq, vec)
        csv_row(f"fig15/seq/envs{n}/steps_per_sec", round(seq, 1))
        csv_row(f"fig15/vec/envs{n}/steps_per_sec", round(vec, 1))
        csv_row(f"fig15/vec_vs_seq/envs{n}", round(vec / max(seq, 1e-9), 2),
                "vmapped dispatch amortized over the batch")
        if smoke:
            assert seq > 0 and vec > 0, "acting produced no steps"
    if not smoke:
        top = env_counts[-1]
        ratio = results[top][1] / max(results[top][0], 1e-9)
        csv_row(f"fig15/acceptance/vec{top}x_speedup", round(ratio, 2),
                "acceptance: >= 3x at 16 envs")
        assert ratio >= 3.0, (
            f"vectorized acting at {top} envs only {ratio:.2f}x sequential")

    num_actors = 2 if smoke else SERVER_ACTORS
    target = SMOKE_SERVER_TARGET_STEPS if smoke else SERVER_TARGET_STEPS
    mode_names = (("local", "server") if smoke
                  else ("local", "server-nobatch", "server"))
    modes = {}
    for mode in mode_names:
        r = run_inference_mode(mode, num_actors, target)
        modes[mode] = r
        csv_row(f"fig15/{mode}/actors{num_actors}/steps_per_sec",
                round(r["steps_per_sec"], 1))
        if smoke:
            assert r["steps"] > 0, f"{mode} inference produced no steps"
    if modes["server"]["inference"] is not None:
        stats = modes["server"]["inference"]
        csv_row("fig15/server/avg_rows_per_batch",
                round(stats["avg_rows_per_batch"], 2),
                "coalescing across actor workers")
        assert stats["batches"] > 0, "inference server never ran a batch"
    if not smoke:
        ratio = (modes["server"]["steps_per_sec"]
                 / max(modes["server-nobatch"]["steps_per_sec"], 1e-9))
        csv_row("fig15/acceptance/server_vs_per_actor_dispatch",
                round(ratio, 2),
                f"coalesced vs one-dispatch-per-request at "
                f"{num_actors} actors")
        assert ratio > 1.0, (
            f"coalescing ({modes['server']['steps_per_sec']:.1f} steps/s) "
            f"did not beat per-actor dispatch "
            f"({modes['server-nobatch']['steps_per_sec']:.1f} steps/s)")
        csv_row("fig15/server_vs_local",
                round(modes["server"]["steps_per_sec"]
                      / max(modes["local"]["steps_per_sec"], 1e-9), 2),
                "vs per-actor LOCAL copies — centralizing pays once the "
                f"policy outgrows the RPC hop (hidden={SERVER_HIDDEN})")
    return results, modes


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
