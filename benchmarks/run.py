"""Benchmark harness — one section per paper table/figure.

Prints ``name,value,derived`` CSV rows.  ``--fast`` shrinks episode budgets;
``--only fig7`` runs a single section.  The roofline section reads the
dry-run sweep output (results/dryrun_baseline.jsonl).
"""
import argparse
import sys
import time
import traceback


SECTIONS = [
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline"),
    ("fig5", "benchmarks.fig5_control"),
    ("fig6", "benchmarks.fig6_distributed_scaling"),
    ("fig7", "benchmarks.fig7_rate_limiter"),
    ("fig9", "benchmarks.fig9_discrete"),
    ("fig10", "benchmarks.fig10_bsuite"),
    ("fig11", "benchmarks.fig11_demos"),
    ("fig12", "benchmarks.fig12_offline"),
    ("fig13", "benchmarks.fig13_replay_sharding"),
    ("fig14", "benchmarks.fig14_actor_scaling"),
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None)
    p.add_argument("--fast", action="store_true")
    args = p.parse_args()

    failures = 0
    for name, module_name in SECTIONS:
        if args.only and args.only != name:
            continue
        print(f"# === {name} ({module_name}) ===", flush=True)
        t0 = time.time()
        try:
            import importlib
            mod = importlib.import_module(module_name)
            if name == "fig10":
                mod.main(fast=args.fast)
            else:
                mod.main()
            print(f"{name}/section_wall_s,{round(time.time() - t0, 1)},")
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"{name}/FAILED,{type(e).__name__},{e}")
        sys.stdout.flush()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
