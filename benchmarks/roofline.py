"""Roofline table from the dry-run sweep (results/dryrun_baseline.jsonl).

Per (arch x shape x mesh): the three roofline terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and the fits-HBM bit.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_row

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun_baseline.jsonl")


def load(path=DEFAULT_PATH):
    recs = {}
    if not os.path.exists(path):
        return recs
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"], r.get("tag", "baseline"))] = r
    return recs


def main(path=DEFAULT_PATH, mesh="single"):
    recs = load(path)
    if not recs:
        csv_row("roofline/missing", 1,
                "run: python -m repro.launch.dryrun --all --mesh both")
        return {}
    n_ok = n_fit = 0
    for (arch, shape, m, tag), r in sorted(recs.items()):
        if m != mesh or tag != "baseline":
            continue
        if r["status"] == "skipped":
            csv_row(f"roofline/{arch}/{shape}", "skipped", r["reason"][:60])
            continue
        if r["status"] != "ok":
            csv_row(f"roofline/{arch}/{shape}", "ERROR", r.get("error", "")[:60])
            continue
        n_ok += 1
        rf = r["roofline"]
        fits = r["memory"].get("fits_hbm")
        n_fit += bool(fits)
        csv_row(
            f"roofline/{arch}/{shape}",
            rf["dominant"],
            f"c={rf['compute_s']:.4f}s m={rf['memory_s']:.4f}s "
            f"n={rf['collective_s']:.4f}s useful={rf['useful_flops_ratio']:.2f} "
            f"fits={fits}")
    csv_row("roofline/num_ok", n_ok)
    csv_row("roofline/num_fits_hbm", n_fit)
    return recs


if __name__ == "__main__":
    main()
