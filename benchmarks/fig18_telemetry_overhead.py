"""Fig 18: telemetry overhead — instrumented vs off actor+learner
steps/sec.

The deal telemetry offers (§4.2's logging philosophy extended to hot
paths) is "leave it on": disabled metrics are shared falsy nulls, so a
``telemetry=False`` run pays one truthiness check per event and never
reads the clock; an enabled run pays two ``time.monotonic()`` calls and
one locked reservoir update per measured event.  This figure prices both
sides against the same single-process DQN-on-Catch agent — the
synchronous actor+learner lockstep drives every instrumented hot path
(replay block timing on insert AND sample) at the highest event rate per
wall-second of any execution mode, so it is the worst case for overhead.

Method: PAIRED interleaving.  Independent off-run/on-run A/B timing is
hopeless here — a shared CI host's throttling swings whole-run steps/sec
by ±10-15%, drowning a sub-3% effect no matter how runs are ordered or
summarized.  Instead both agents live in ONE process (the off agent's
tables cache null metrics before telemetry is enabled; the on agent's
cache live histograms) and the clock alternates between them in small
episode batches, so every throttle burst hits both arms in expectation
and the accumulated per-arm times stay comparable.  Repeated invocations
of this figure land within ~±1.5% of each other, versus ±10% for the
unpaired design.  Acceptance: overhead < 3% of actor steps/sec.

    python benchmarks/fig18_telemetry_overhead.py            # full sweep
    python benchmarks/fig18_telemetry_overhead.py --smoke    # CI check
"""
from __future__ import annotations

import sys
import time

from benchmarks.common import csv_row
from repro.agents.builders import make_agent
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.core import EnvironmentLoop, make_environment_spec
from repro.envs import Catch
from repro.telemetry import registry as _telemetry

WARMUP_EPISODES = 10
BATCHES = 60
EPISODES_PER_BATCH = 10
SMOKE_WARMUP_EPISODES = 8
SMOKE_BATCHES = 50
SMOKE_EPISODES_PER_BATCH = 8
OVERHEAD_BUDGET_PCT = 3.0


def builder_factory(spec):
    # min_replay_size small so the timed batches are steady-state lockstep
    # (insert + learner sampling every tick) rather than replay warm-fill;
    # samples_per_insert=1 exercises the rate-limiter timing path on both
    # insert and sample throughout.
    return DQNBuilder(spec, DQNConfig(min_replay_size=16,
                                      samples_per_insert=1.0,
                                      batch_size=8, n_step=1), seed=0)


def env_factory(seed):
    return Catch(seed=seed)


def _build_loop(telemetry: bool, warmup: int, seed: int = 0):
    """One agent + loop, warmed past jit compiles and replay fill.

    Ordering contract with the process-global registry: the OFF loop is
    built (and warmed) first, while the registry is disabled, so its
    tables cache the null metric forever; the ON loop's ``make_agent``
    then re-enables the registry and its tables cache live histograms.
    """
    env = env_factory(seed)
    spec = make_environment_spec(env)
    agent = make_agent(builder_factory(spec), seed=seed, telemetry=telemetry)
    loop = EnvironmentLoop(env, agent)
    for _ in range(warmup):
        loop.run_episode()
    return loop, agent


def measure(warmup: int, batches: int, episodes_per_batch: int):
    loop_off, agent_off = _build_loop(False, warmup)
    loop_on, agent_on = _build_loop(True, warmup)
    assert _telemetry.enabled()
    inserts_before = _telemetry.snapshot()[
        "replay/insert_block_ms"]["count"]
    agents = {False: agent_off, True: agent_on}
    loops = {False: loop_off, True: loop_on}
    wall = {False: 0.0, True: 0.0}
    steps = {False: 0, True: 0}
    learner0 = {arm: int(agents[arm].learner.state.steps)
                for arm in (False, True)}
    for batch in range(batches):
        # alternate which arm leads so within-pair drift cancels too
        order = (False, True) if batch % 2 == 0 else (True, False)
        for arm in order:
            loop = loops[arm]
            t0 = time.monotonic()
            for _ in range(episodes_per_batch):
                steps[arm] += loop.run_episode()["episode_length"]
            wall[arm] += time.monotonic() - t0
    learner_steps = {arm: int(agents[arm].learner.state.steps) - learner0[arm]
                     for arm in (False, True)}
    # purity: recorded events during the timed phase came from the ON
    # agent alone — the OFF agent's cached nulls never observed anything
    recorded = _telemetry.snapshot()[
        "replay/insert_block_ms"]["count"] - inserts_before
    assert 0 < recorded <= steps[True] + episodes_per_batch * batches, (
        f"off arm leaked into telemetry: {recorded} events for "
        f"{steps[True]} instrumented steps")
    return {"off_sps": steps[False] / wall[False],
            "on_sps": steps[True] / wall[True],
            "off_lps": learner_steps[False] / wall[False],
            "on_lps": learner_steps[True] / wall[True]}


def main(smoke: bool = False):
    warmup = SMOKE_WARMUP_EPISODES if smoke else WARMUP_EPISODES
    batches = SMOKE_BATCHES if smoke else BATCHES
    per_batch = SMOKE_EPISODES_PER_BATCH if smoke else EPISODES_PER_BATCH
    r = measure(warmup, batches, per_batch)
    overhead_pct = (r["off_sps"] - r["on_sps"]) / r["off_sps"] * 100.0
    csv_row("fig18/off/actor_steps_per_sec", round(r["off_sps"], 1))
    csv_row("fig18/on/actor_steps_per_sec", round(r["on_sps"], 1))
    csv_row("fig18/off/learner_steps_per_sec", round(r["off_lps"], 1))
    csv_row("fig18/on/learner_steps_per_sec", round(r["on_lps"], 1))
    csv_row("fig18/overhead_pct", round(overhead_pct, 2),
            f"acceptance <{OVERHEAD_BUDGET_PCT}%")
    assert overhead_pct < OVERHEAD_BUDGET_PCT, (
        f"telemetry overhead {overhead_pct:.2f}% exceeds the "
        f"{OVERHEAD_BUDGET_PCT}% budget "
        f"(off={r['off_sps']:.1f} on={r['on_sps']:.1f} steps/sec)")
    if smoke:
        print(f"fig18 smoke OK: overhead {overhead_pct:.2f}% "
              f"(off={r['off_sps']:.1f} on={r['on_sps']:.1f} "
              f"actor steps/sec)")
    return {**r, "overhead_pct": overhead_pct}


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
