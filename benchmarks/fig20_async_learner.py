"""Fig 20: async vs barrier learner throughput under a straggling replica.

The payoff figure for ``learner_sync="async"``: two learner replicas, one
artificially slowed (every SGD step sleeps), trained through the UNCHANGED
``DQNBuilder`` under both synchronization modes.  With the barrier
``ParameterServer`` the fast replica parks at every averaging rendezvous
until the straggler catches up, so fleet throughput degrades to ~2x the
straggler's rate.  With the push/pull ``AsyncParameterService`` the fast
replica free-runs and the straggler only costs the blend staleness — the
aggregate SGD rate stays near the sum of the replicas' natural rates.

Method: both runs warm up until every replica has taken a few steps (the
first step pays the jit compile, which on a 1-core CI container can skew a
replica by seconds), then aggregate learner steps are counted over a fixed
wall-clock window.  The honest caveat: async throughput is not async
gradient quality — staleness costs convergence; the learning-quality
evidence lives in ``tests/test_async_learner.py``.

    python benchmarks/fig20_async_learner.py            # full measure
    python benchmarks/fig20_async_learner.py --smoke    # CI mechanics check
"""
from __future__ import annotations

import sys
import time

from benchmarks.common import csv_row
from repro.agents.builders import make_distributed_agent
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.core import make_environment_spec
from repro.envs import Catch

AVERAGE_PERIOD = 10
SLOW_STEP_S = 0.05          # injected per-step delay of the straggler
WARMUP_STEPS = 2            # every replica past its jit-compiling step
WARMUP_TIMEOUT_S = 120.0
MEASURE_S = 20.0
SMOKE_MEASURE_S = 6.0
# The --smoke bar: the async fleet must beat the barrier fleet by at least
# this factor under the injected straggler (the measured gap is ~3-5x; 1.5
# leaves room for CI noise without letting a regression to barrier-like
# blocking slip through).
SMOKE_MIN_SPEEDUP = 1.5


# Module-level factories: picklable for process-crossing backends.
def builder_factory(spec):
    # samples_per_insert=0 -> MinSize limiter: replicas step unthrottled,
    # so the figure measures SGD scheduling, not the SPI schedule.
    return DQNBuilder(spec, DQNConfig(min_replay_size=32,
                                      samples_per_insert=0.0,
                                      batch_size=16, n_step=1), seed=0)


def env_factory(seed):
    return Catch(seed=seed)


class SlowLearner:
    """Delegating learner whose every step sleeps first — the straggler.

    time.sleep releases the GIL, so on a 1-core host the fast replica
    keeps the interpreter while the straggler 'computes'.
    """

    def __init__(self, inner, sleep_s: float):
        self.inner = inner
        self.sleep_s = sleep_s

    def step(self):
        time.sleep(self.sleep_s)
        return self.inner.step()

    @property
    def state(self):
        return self.inner.state

    @state.setter
    def state(self, value):
        self.inner.state = value

    def get_variables(self, names=("policy",)):
        return self.inner.get_variables(names)


class SlowFirstReplicaBuilder:
    """Delegating builder: the FIRST make_learner call (replica 0) gets a
    ``SlowLearner`` wrapper; everything else passes straight through."""

    def __init__(self, inner, sleep_s: float):
        self.inner = inner
        self.sleep_s = sleep_s
        self.learners_made = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def make_learner(self, dataset, **kwargs):
        learner = self.inner.make_learner(dataset, **kwargs)
        self.learners_made += 1
        if self.learners_made == 1:
            return SlowLearner(learner, self.sleep_s)
        return learner


def run_one(sync: str, measure_s: float):
    spec = make_environment_spec(env_factory(0))
    builder = SlowFirstReplicaBuilder(builder_factory(spec), SLOW_STEP_S)
    dist = make_distributed_agent(
        builder, env_factory, num_actors=1, seed=0,
        num_learner_replicas=2, learner_average_period=AVERAGE_PERIOD,
        learner_sync=sync)
    try:
        t0 = time.time()
        while time.time() - t0 < WARMUP_TIMEOUT_S:
            steps = dist.learner_stats()["per_replica_steps"]
            if all(s >= WARMUP_STEPS for s in steps):
                break
            time.sleep(0.1)
        else:
            raise RuntimeError(
                f"{sync}: replicas never warmed up: "
                f"{dist.learner_stats()['per_replica_steps']}")
        start = dist.learner_stats()["per_replica_steps"]
        t1 = time.time()
        time.sleep(measure_s)
        end_stats = dist.learner_stats()
        wall = time.time() - t1
    finally:
        dist.stop()
    per_replica = [e - s for s, e in zip(start,
                                         end_stats["per_replica_steps"])]
    total = sum(per_replica)
    return {"sgd_per_sec": total / max(wall, 1e-9),
            "per_replica": per_replica,
            "rounds": end_stats["rounds"]}


def main(smoke: bool = False):
    measure_s = SMOKE_MEASURE_S if smoke else MEASURE_S
    results = {}
    for sync in ("barrier", "async"):
        r = run_one(sync, measure_s)
        results[sync] = r
        csv_row(f"fig20/{sync}/sgd_steps_per_sec",
                round(r["sgd_per_sec"], 1))
        csv_row(f"fig20/{sync}/per_replica_steps", r["per_replica"])
        csv_row(f"fig20/{sync}/rounds", r["rounds"])
    speedup = (results["async"]["sgd_per_sec"]
               / max(results["barrier"]["sgd_per_sec"], 1e-9))
    csv_row("fig20/async_over_barrier_speedup", round(speedup, 2))
    if smoke:
        for sync, r in results.items():
            assert all(s > 0 for s in r["per_replica"]), (
                f"{sync}: a replica never stepped in the window: {r}")
            assert r["rounds"] >= 1, (
                f"{sync}: no parameter exchange completed: {r}")
        assert speedup >= SMOKE_MIN_SPEEDUP, (
            f"async fleet only {speedup:.2f}x the barrier fleet under a "
            f"{SLOW_STEP_S * 1000:.0f}ms/step straggler (expected >= "
            f"{SMOKE_MIN_SPEEDUP}x): {results}")
        print(f"fig20 smoke OK: {speedup:.2f}x",
              {s: r["per_replica"] for s, r in results.items()})
    return results


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
