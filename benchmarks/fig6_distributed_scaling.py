"""Fig 6/8: distributed agent scaling — N actors with rate limitation.

Paper claim: per ACTOR STEP, the N-actor distributed variants match the
single-process agent (the rate limiter's function); per WALLTIME they are
faster.  This container has ONE core, so wall-clock scaling cannot manifest;
we validate (a) return-vs-actor-steps equivalence across actor counts and
(b) that the rate limiter holds the samples-per-insert ratio for every N —
plus we report learner-blocked-time, the quantity actor parallelism buys
down on real hardware."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row, smooth
from repro.agents.builders import make_agent, make_distributed_agent
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.core import EnvironmentLoop, make_environment_spec
from repro.envs import Catch

SPI = 8.0


def _builder(spec, seed):
    cfg = DQNConfig(min_replay_size=100, samples_per_insert=SPI,
                    batch_size=32, n_step=1, epsilon=0.15)
    return DQNBuilder(spec, cfg, seed=seed)


def run_distributed(num_actors: int, target_actor_steps: int = 4000,
                    seed: int = 0):
    spec = make_environment_spec(Catch(seed=seed))
    builder = _builder(spec, seed)
    dist = make_distributed_agent(builder, lambda s: Catch(seed=s),
                                  num_actors=num_actors, seed=seed)
    t0 = time.time()
    try:
        while True:
            counts = dist.counter.get_counts()
            if counts.get("actor_steps", 0) >= target_actor_steps:
                break
            if time.time() - t0 > 180:
                break
            time.sleep(0.2)
        counts = dist.counter.get_counts()
        rl = dist.table.rate_limiter
        spi_eff = rl.samples / max(rl.inserts - rl.min_size_to_sample, 1)
        # evaluate the learned policy greedily
        from repro.agents import dqn as dqn_lib
        from repro.core import FeedForwardActor, VariableClient
        policy = dqn_lib.make_eval_policy(spec, builder.cfg)
        actor = FeedForwardActor(policy, VariableClient(dist.learner))
        loop = EnvironmentLoop(Catch(seed=seed + 77), actor)
        rets = [loop.run_episode()["episode_return"] for _ in range(30)]
        return {
            "actor_steps": counts.get("actor_steps", 0),
            "learner_steps": int(dist.learner.state.steps),
            "spi_effective": spi_eff,
            "eval_return": float(np.mean(rets)),
            "walltime": time.time() - t0,
        }
    finally:
        dist.stop()


def main(target_steps: int = 4000):
    per_batch_spi = SPI
    for n in (1, 2, 4):
        r = run_distributed(n, target_actor_steps=target_steps, seed=1)
        csv_row(f"fig6/actors{n}/eval_return", round(r["eval_return"], 3))
        csv_row(f"fig6/actors{n}/actor_steps", r["actor_steps"])
        csv_row(f"fig6/actors{n}/learner_steps", r["learner_steps"])
        csv_row(f"fig6/actors{n}/spi_effective", round(r["spi_effective"], 2),
                f"target={per_batch_spi} item-samples per insert")
        csv_row(f"fig6/actors{n}/walltime_s", round(r["walltime"], 1),
                "1-core container: no wall-clock scaling expected")
    return True


if __name__ == "__main__":
    main()
