"""Fig 6/8: distributed agent scaling — N actors with rate limitation.

Paper claim: per ACTOR STEP, the N-actor distributed variants match the
single-process agent (the rate limiter's function); per WALLTIME they are
faster.  This container has ONE core, so wall-clock scaling cannot manifest;
we validate (a) return-vs-actor-steps equivalence across actor counts and
(b) that the rate limiter holds the samples-per-insert ratio for every N —
plus we report learner-blocked-time, the quantity actor parallelism buys
down on real hardware."""
from __future__ import annotations

from benchmarks.common import csv_row
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.envs import Catch
from repro.experiments import ExperimentConfig, run_distributed_experiment

SPI = 8.0


def _config(seed: int, target_actor_steps: int) -> ExperimentConfig:
    cfg = DQNConfig(min_replay_size=100, samples_per_insert=SPI,
                    batch_size=32, n_step=1, epsilon=0.15)
    return ExperimentConfig(
        builder_factory=lambda spec: DQNBuilder(spec, cfg, seed=seed),
        environment_factory=lambda s: Catch(seed=s),
        seed=seed, max_actor_steps=target_actor_steps, eval_episodes=30)


def run_distributed(num_actors: int, target_actor_steps: int = 4000,
                    seed: int = 0):
    result = run_distributed_experiment(
        _config(seed, target_actor_steps), num_actors=num_actors,
        timeout_s=180)
    ex = result.extras
    return {
        "actor_steps": result.counts.get("actor_steps", 0),
        "learner_steps": result.learner_steps,
        "spi_effective": ex["spi_effective"],
        "eval_return": result.final_eval_return,
        "walltime": ex["walltime"],
    }


def main(target_steps: int = 4000):
    per_batch_spi = SPI
    for n in (1, 2, 4):
        r = run_distributed(n, target_actor_steps=target_steps, seed=1)
        csv_row(f"fig6/actors{n}/eval_return", round(r["eval_return"], 3))
        csv_row(f"fig6/actors{n}/actor_steps", r["actor_steps"])
        csv_row(f"fig6/actors{n}/learner_steps", r["learner_steps"])
        csv_row(f"fig6/actors{n}/spi_effective", round(r["spi_effective"], 2),
                f"target={per_batch_spi} item-samples per insert")
        csv_row(f"fig6/actors{n}/walltime_s", round(r["walltime"], 1),
                "1-core container: no wall-clock scaling expected")
    return True


if __name__ == "__main__":
    main()
