"""Shared benchmark machinery: run agents, collect (actor_steps, return)
curves, emit CSV rows ``name,value,derived``."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np


def run_single_process(env_factory, builder, episodes: int,
                       seed: int = 0) -> Dict[str, List[float]]:
    """Returns {actor_steps: [...], returns: [...], walltime: [...]}."""
    from repro.agents.builders import make_agent
    from repro.core import EnvironmentLoop

    env = env_factory(seed)
    agent = make_agent(builder, seed=seed)
    loop = EnvironmentLoop(env, agent)
    steps, rets, wall = [], [], []
    total_steps = 0
    t0 = time.time()
    for _ in range(episodes):
        r = loop.run_episode()
        total_steps += r["episode_length"]
        steps.append(total_steps)
        rets.append(r["episode_return"])
        wall.append(time.time() - t0)
    return {"actor_steps": steps, "returns": rets, "walltime": wall,
            "learner_steps": int(agent.learner.state.steps)
            if hasattr(agent.learner.state, "steps") else 0}


def smooth(xs, k=20):
    xs = np.asarray(xs, np.float64)
    if len(xs) < k:
        return xs
    return np.convolve(xs, np.ones(k) / k, mode="valid")


def csv_row(name: str, value, derived: str = ""):
    print(f"{name},{value},{derived}")


def curve_summary(name: str, result: Dict, head: int = 30, tail: int = 30):
    rets = result["returns"]
    head_m = float(np.mean(rets[:head]))
    tail_m = float(np.mean(rets[-tail:]))
    csv_row(f"{name}/first{head}_return", round(head_m, 3))
    csv_row(f"{name}/last{tail}_return", round(tail_m, 3))
    csv_row(f"{name}/improvement", round(tail_m - head_m, 3),
            "positive=learning")
    csv_row(f"{name}/actor_steps", result["actor_steps"][-1])
    return tail_m
