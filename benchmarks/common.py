"""Shared benchmark machinery: run agents, collect (actor_steps, return)
curves, emit CSV rows ``name,value,derived``."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np


def run_single_process(env_factory, builder, episodes: int,
                       seed: int = 0) -> Dict[str, List[float]]:
    """Returns {actor_steps: [...], returns: [...], walltime: [...]}.

    Thin adapter over ``repro.experiments.run_experiment`` so every curve
    benchmark runs through the experiments API.
    """
    from repro.experiments import ExperimentConfig, run_experiment

    config = ExperimentConfig(builder_factory=lambda spec: builder,
                              environment_factory=env_factory,
                              seed=seed, num_episodes=episodes,
                              eval_episodes=0)
    result = run_experiment(config)
    return {"actor_steps": result.actor_steps,
            "returns": result.train_returns,
            "walltime": result.walltime,
            "learner_steps": result.learner_steps}


def smooth(xs, k=20):
    xs = np.asarray(xs, np.float64)
    if len(xs) < k:
        return xs
    return np.convolve(xs, np.ones(k) / k, mode="valid")


def csv_row(name: str, value, derived: str = ""):
    print(f"{name},{value},{derived}")


def curve_summary(name: str, result: Dict, head: int = 30, tail: int = 30):
    rets = result["returns"]
    head_m = float(np.mean(rets[:head]))
    tail_m = float(np.mean(rets[-tail:]))
    csv_row(f"{name}/first{head}_return", round(head_m, 3))
    csv_row(f"{name}/last{tail}_return", round(tail_m, 3))
    csv_row(f"{name}/improvement", round(tail_m - head_m, 3),
            "positive=learning")
    csv_row(f"{name}/actor_steps", result["actor_steps"][-1])
    return tail_m
