"""Fig 5: single-process continuous-control agents (DDPG, D4PG, MPO, DMPO)
on control-from-features tasks — all four learn; D4PG/DMPO (distributional)
match or beat their expected-value counterparts."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, curve_summary, run_single_process
from repro.agents.continuous import ContinuousBuilder, ContinuousConfig
from repro.core import make_environment_spec
from repro.envs import PendulumSwingup

EPISODE_LEN = 120
EPISODES = 50


def _cfg(algo):
    return ContinuousConfig(
        algo=algo, hidden=64, batch_size=64, min_replay_size=300,
        samples_per_insert=0.0, n_step=3, sigma=0.3,
        vmin=0.0, vmax=float(EPISODE_LEN), num_atoms=31,
        target_update_period=50, mpo_samples=8)


def main(episodes: int = EPISODES):
    env_factory = lambda seed: PendulumSwingup(seed=seed,
                                               episode_len=EPISODE_LEN)
    spec = make_environment_spec(env_factory(0))
    finals = {}
    for algo in ("ddpg", "d4pg", "mpo", "dmpo"):
        builder = ContinuousBuilder(spec, _cfg(algo), seed=3)
        result = run_single_process(env_factory, builder, episodes, seed=3)
        finals[algo] = curve_summary(f"fig5/{algo}", result, head=10, tail=10)
    csv_row("fig5/all_learn",
            int(all(finals[a] > 5 for a in finals) and
                max(finals.values()) > 30),
            "all improve; best agent > 30/120 on pendulum swingup")
    return finals


if __name__ == "__main__":
    main()
