"""Fig 17: transformer policy serving — batched prefill, decode kernel,
and inference placement once the policy is a transformer.

Three claims behind ``repro.policies``:

1. **Batched prefill** (tier 1): pushing a whole prompt window through the
   KV cache in ONE jitted call (``make_batched_prefill_step``) beats the
   token-at-a-time ``serve_step`` replay loop the server previously used.
   Acceptance: >= 4x prefill tokens/sec on the reduced serve arch.

2. **Decode kernel parity shapes** (report only): ``decode_attention``
   kernel vs the ``kernels/ref.py`` oracle at the exact shapes the policy
   serve step emits (power-of-two padded slot batches over window-length
   ring caches).  On CPU the kernel runs in interpret mode — orders of
   magnitude slower, which is exactly why ``backend="auto"`` resolves to
   "ref" off-TPU; the rows document both sides of that fallback rule.

3. **Inference placement** (tier 2, SEED-style): multiprocess actors with
   ``inference="server"`` — windows over RPC into ONE continuous-batching
   engine with per-episode cache slots — vs per-actor LOCAL engines, swept
   over policy ``d_model``.  Small policies win locally (the RPC hop costs
   more than the forward pass); acceptance is that the server wins at the
   largest benchmarked policy.

    python benchmarks/fig17_transformer_serving.py            # full sweep
    python benchmarks/fig17_transformer_serving.py --smoke    # CI mechanics
"""
from __future__ import annotations

import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.configs import get_arch, reduced
from repro.experiments import ExperimentConfig, run_distributed_experiment
from repro.kernels import ops, ref
from repro.launch.serve import BatchedServer

PREFILL_SLOTS = 8
PREFILL_LEN = 32
PREFILL_ITERS = 20
SMOKE_PREFILL_ITERS = 2

DECODE_SHAPES = ((8, 2, 8, 16), (8, 4, 16, 32))   # (slots, heads, window, d)
DECODE_ITERS = 50

D_MODELS = (64, 256)
SMOKE_D_MODELS = (32,)
SERVER_ACTORS = 4
SERVER_TARGET_STEPS = 3000
SMOKE_SERVER_TARGET_STEPS = 200
TIMEOUT_S = 300.0


# Module-level factories: the multiprocess backend pickles them into
# spawned actor processes (by reference to this module plus instance state).
class PolicyBuilderFactory:
    """Picklable ``spec -> TransformerPolicyBuilder`` at one ``d_model``."""

    def __init__(self, d_model: int):
        self.d_model = d_model

    def __call__(self, spec):
        from repro.policies import (TransformerPolicyBuilder,
                                    TransformerPolicyConfig)
        d = self.d_model
        # samples_per_insert=0 -> MinSize limiter: actors run unthrottled,
        # so the figure measures serving throughput, not the SPI schedule.
        cfg = TransformerPolicyConfig(
            num_layers=2, d_model=d, num_heads=4, num_kv_heads=2,
            head_dim=max(d // 4, 8), d_ff=2 * d, window=8,
            sequence_length=16, period=8, batch_size=16,
            min_replay_size=100, samples_per_insert=0.0, backend="auto")
        return TransformerPolicyBuilder(spec, cfg, seed=0)


def env_factory(seed):
    from repro.envs import Catch
    return Catch(seed=seed)


# ------------------------------------------------- tier 1: batched prefill
def run_prefill(batched: bool, iters: int) -> float:
    """Prefill tokens/sec through a fresh ``BatchedServer`` cache."""
    cfg = reduced(get_arch("qwen3-1.7b"))
    server = BatchedServer(cfg, PREFILL_SLOTS, PREFILL_LEN,
                           batched_prefill=batched)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size,
                          (PREFILL_SLOTS, PREFILL_LEN)).astype(np.int32)
    fresh_cache = server.cache
    np.asarray(server.prefill(prompts))     # compile outside the window
    t0 = time.perf_counter()
    for _ in range(iters):
        server.cache = fresh_cache
        np.asarray(server.prefill(prompts))
    wall = time.perf_counter() - t0
    return iters * PREFILL_SLOTS * PREFILL_LEN / wall


# ------------------------------------- report: decode kernel vs ref oracle
def run_decode_shapes(iters: int):
    """Tokens/sec for kernel (interpret off-TPU) vs ref at serve shapes."""
    rng = np.random.RandomState(1)
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    for b, h, s, d in DECODE_SHAPES:
        q = jnp.asarray(rng.randn(b, h, d), jnp.float32)
        k = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
        v = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
        lengths = jnp.asarray(rng.randint(1, s + 1, b), jnp.int32)

        def timed(fn, n):
            np.asarray(fn(q, k, v, lengths))      # warm / compile
            t0 = time.perf_counter()
            for _ in range(n):
                np.asarray(fn(q, k, v, lengths))
            return n * b / (time.perf_counter() - t0)

        ref_fn = jax.jit(ref.decode_attention_ref)
        ref_tps = timed(ref_fn, iters)
        # interpret mode is a functional check, not a perf mode — one call.
        kernel_fn = lambda *a: ops.decode_attention(
            *a, block_k=min(512, s), interpret=not on_tpu)
        kernel_tps = timed(kernel_fn, 1 if not on_tpu else iters)
        rows.append((b, h, s, d, ref_tps, kernel_tps))
        tag = f"b{b}h{h}s{s}d{d}"
        csv_row(f"fig17/decode/{tag}/ref_rows_per_sec", round(ref_tps, 1))
        csv_row(f"fig17/decode/{tag}/kernel_rows_per_sec",
                round(kernel_tps, 1),
                "interpret mode (CPU) — why auto->ref off-TPU"
                if not on_tpu else "pallas kernel")
    return rows


# --------------------------------------------- tier 2: inference placement
def run_placement(mode: str, d_model: int, num_actors: int,
                  target_steps: int):
    config = ExperimentConfig(
        builder_factory=PolicyBuilderFactory(d_model),
        environment_factory=env_factory,
        seed=0, eval_episodes=0, launcher="multiprocess", inference=mode)
    result = run_distributed_experiment(
        config, num_actors=num_actors, max_actor_steps=target_steps,
        timeout_s=TIMEOUT_S)
    steps = int(result.counts.get("actor_steps", 0))
    wall = result.extras["walltime"]
    return {"steps": steps, "wall": wall,
            "steps_per_sec": steps / max(wall, 1e-9),
            "inference": result.extras.get("inference")}


def main(smoke: bool = False):
    # -- tier 1: batched vs token-at-a-time prefill
    iters = SMOKE_PREFILL_ITERS if smoke else PREFILL_ITERS
    token_tps = run_prefill(batched=False, iters=iters)
    batch_tps = run_prefill(batched=True, iters=iters)
    ratio = batch_tps / max(token_tps, 1e-9)
    csv_row("fig17/prefill/token_at_a_time/tokens_per_sec",
            round(token_tps, 1))
    csv_row("fig17/prefill/batched/tokens_per_sec", round(batch_tps, 1))
    csv_row("fig17/prefill/batched_vs_token", round(ratio, 2),
            "one jitted call vs serve_step replay loop")
    if smoke:
        assert token_tps > 0 and batch_tps > 0, "prefill produced no tokens"
    else:
        assert ratio >= 4.0, (
            f"batched prefill only {ratio:.2f}x token-at-a-time")

    # -- report: decode kernel vs ref at policy serve shapes
    run_decode_shapes(2 if smoke else DECODE_ITERS)

    # -- tier 2: server vs local placement over policy size
    d_models = SMOKE_D_MODELS if smoke else D_MODELS
    num_actors = 2 if smoke else SERVER_ACTORS
    target = SMOKE_SERVER_TARGET_STEPS if smoke else SERVER_TARGET_STEPS
    placements = {}
    for d in d_models:
        for mode in ("local", "server"):
            r = run_placement(mode, d, num_actors, target)
            placements[(d, mode)] = r
            csv_row(f"fig17/{mode}/d{d}/steps_per_sec",
                    round(r["steps_per_sec"], 1))
            if smoke:
                assert r["steps"] > 0, (
                    f"{mode} inference at d_model={d} produced no steps")
        server = placements[(d, "server")]
        if server["inference"] is not None:
            stats = server["inference"]
            csv_row(f"fig17/server/d{d}/avg_rows_per_batch",
                    round(stats.get("avg_rows_per_batch", 0.0), 2))
            csv_row(f"fig17/server/d{d}/decode_rows",
                    stats.get("decode_rows", 0),
                    "incremental KV-cache decode on the hot path")
            csv_row(f"fig17/server/d{d}/prefill_rows",
                    stats.get("prefill_rows", 0),
                    "episode starts + stale-cache re-prefills")
            assert stats.get("decode_rows", 0) > 0, (
                "server answered every row by prefill — the KV cache "
                "slots are not being continued")
    if not smoke:
        top = d_models[-1]
        gain = (placements[(top, "server")]["steps_per_sec"]
                / max(placements[(top, "local")]["steps_per_sec"], 1e-9))
        csv_row(f"fig17/acceptance/server_vs_local_d{top}", round(gain, 2),
                "centralized inference pays once the policy outgrows "
                "the RPC hop")
        assert gain > 1.0, (
            f"server ({placements[(top, 'server')]['steps_per_sec']:.1f} "
            f"steps/s) did not beat local "
            f"({placements[(top, 'local')]['steps_per_sec']:.1f} steps/s) "
            f"at d_model={top}")
    return placements


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
