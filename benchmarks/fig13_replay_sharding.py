"""Fig 13: replay-service sharding — aggregate throughput vs shard count.

The §2.5 rate limiter couples every actor and learner through one condition
variable: with a production-tight error buffer the table admits only a couple
of operations between forced handoffs, so a single table is bound by
blocked-thread wakeups (notify_all storms over every waiter + lock convoy),
far below CPU bound.  ``ShardedReplay`` gives each shard its own table,
selector, and limiter: the coupling — and the wakeups — become per shard,
handoffs pipeline across shards, and the service's aggregate throughput
recovers with the shard count.

Workload (identical at every shard count): ``ACTORS`` insert threads and
``LEARNERS`` sample threads hammer one replay service; shards are built from
the same ``make_replay``-style factory a builder would supply (Uniform
selector, SPI=1 limiter with a tight error buffer).  Throughput is total
(inserts + samples) / total time over ``TRIALS`` interleaved trials — thread
scheduling is noisy, so single trials are not representative.  The per-shard
SPI invariant is checked after every trial.

Acceptance: >= 2x aggregate throughput at 4 shards vs 1.

    python benchmarks/fig13_replay_sharding.py            # full sweep
    python benchmarks/fig13_replay_sharding.py --smoke    # ~2s CI check
"""
from __future__ import annotations

import sys
import threading
import time

import numpy as np

from benchmarks.common import csv_row
from repro.replay import (RateLimiterTimeout, SampleToInsertRatio, Table,
                          Uniform, make_replay_shards)

SHARD_COUNTS = (1, 2, 4)
ACTORS = 4
LEARNERS = 4
SPI = 1.0
MIN_SIZE = 1
ERROR_BUFFER = 2.0
TRIALS = 3
DURATION = 1.0
ITEM = np.zeros(128, np.float32)


def _make_factory():
    return lambda: Table("fig13", 100_000, Uniform(0),
                         SampleToInsertRatio(SPI, MIN_SIZE,
                                             error_buffer=ERROR_BUFFER))


def run_workload(num_shards: int, duration: float = DURATION,
                 actors: int = ACTORS, learners: int = LEARNERS):
    """One trial: returns (ops, elapsed_s, table) for the fixed workload."""
    table = make_replay_shards(_make_factory(), num_shards)
    deadline = time.time() + duration

    def actor():
        while time.time() < deadline:
            try:
                table.insert(ITEM, timeout=0.5)
            except RateLimiterTimeout:
                pass

    def learner():
        while time.time() < deadline:
            try:
                table.sample(1, timeout=0.5)
            except RateLimiterTimeout:
                pass

    threads = ([threading.Thread(target=actor) for _ in range(actors)]
               + [threading.Thread(target=learner) for _ in range(learners)])
    t0 = time.time()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.time() - t0
    rl = table.rate_limiter
    return rl.inserts + rl.samples, elapsed, table


def check_spi_invariant(table) -> bool:
    """§2.5 per-shard invariant: |samples - spi*(inserts - min_size)| stays
    within the error buffer (+ in-flight slack of one op per worker)."""
    shards = getattr(table, "shards", [table])
    slack = ERROR_BUFFER + SPI * (ACTORS + LEARNERS)
    for shard in shards:
        rl = shard.rate_limiter
        if rl.inserts <= rl.min_size_to_sample:
            continue
        deficit = rl.samples - SPI * (rl.inserts - rl.min_size_to_sample)
        if abs(deficit) > slack:
            return False
    return True


def main(smoke: bool = False):
    duration = 0.2 if smoke else DURATION
    trials = 1 if smoke else TRIALS
    shard_counts = (1, 4) if smoke else SHARD_COUNTS
    ops = {n: 0 for n in shard_counts}
    wall = {n: 0.0 for n in shard_counts}
    invariant = {n: True for n in shard_counts}
    # interleave trials across shard counts so scheduler drift hits all
    # configurations equally
    for _ in range(trials):
        for n in shard_counts:
            count, elapsed, table = run_workload(n, duration=duration)
            ops[n] += count
            wall[n] += elapsed
            invariant[n] &= check_spi_invariant(table)
    throughput = {}
    for n in shard_counts:
        throughput[n] = ops[n] / wall[n]
        csv_row(f"fig13/shards{n}/ops_per_sec", round(throughput[n]))
        csv_row(f"fig13/shards{n}/spi_invariant_held", int(invariant[n]))
        if not smoke:
            assert invariant[n], f"per-shard SPI invariant violated, {n} shards"
    speedup = throughput[shard_counts[-1]] / max(throughput[1], 1e-9)
    csv_row(f"fig13/speedup_{shard_counts[-1]}x_vs_1", round(speedup, 2),
            "claim: >= 2x at 4 shards")
    if not smoke:
        assert speedup >= 2.0, (
            f"sharding speedup {speedup:.2f}x < 2x acceptance threshold")
    return throughput


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
