"""Fig 14: actor scaling — actor steps/sec vs ``num_replicas`` x backend.

The §2.4 claim behind the pluggable launcher API: the SAME program graph
(unchanged ``DQNBuilder``, replicated actor nodes) runs on threads
(``local``) or on one OS process per actor (``multiprocess``), and the
backend choice is a config field, not an agent edit.  This figure sweeps
the actor-pool size over both backends and reports environment-interaction
throughput.

What to expect: on multi-core hosts the multiprocess backend escapes the
GIL — actor throughput scales with replicas while the local backend's
threads serialize on the interpreter lock.  On a 1-core CI container
neither backend can scale in wall-clock; the figure then documents the
courier RPC overhead (weight pulls + replay inserts per step) instead.
Numbers include child startup (spawn + jax import), which is why full mode
runs to a step target large enough to dwarf it.

    python benchmarks/fig14_actor_scaling.py            # full sweep
    python benchmarks/fig14_actor_scaling.py --smoke    # CI mechanics check
"""
from __future__ import annotations

import sys

from benchmarks.common import csv_row
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.envs import Catch
from repro.experiments import ExperimentConfig, run_distributed_experiment

BACKENDS = ("local", "multiprocess")
ACTOR_COUNTS = (1, 2, 4)
TARGET_STEPS = 5000
SMOKE_TARGET_STEPS = 300
TIMEOUT_S = 180.0


# Module-level factories: the multiprocess backend pickles them into
# spawned actor processes.
def builder_factory(spec):
    # samples_per_insert=0 -> MinSize limiter: actors run unthrottled, so
    # the figure measures interaction throughput, not the SPI schedule.
    return DQNBuilder(spec, DQNConfig(min_replay_size=100,
                                      samples_per_insert=0.0,
                                      batch_size=16, n_step=1), seed=0)


def env_factory(seed):
    return Catch(seed=seed)


def run_one(backend: str, num_actors: int, target_steps: int):
    config = ExperimentConfig(
        builder_factory=builder_factory, environment_factory=env_factory,
        seed=0, eval_episodes=0, launcher=backend)
    result = run_distributed_experiment(
        config, num_actors=num_actors, max_actor_steps=target_steps,
        timeout_s=TIMEOUT_S)
    steps = int(result.counts.get("actor_steps", 0))
    wall = result.extras["walltime"]
    return {"steps": steps, "wall": wall,
            "steps_per_sec": steps / max(wall, 1e-9),
            "learner_steps": result.learner_steps}


def main(smoke: bool = False):
    target = SMOKE_TARGET_STEPS if smoke else TARGET_STEPS
    actor_counts = (2,) if smoke else ACTOR_COUNTS
    results = {}
    for backend in BACKENDS:
        for n in actor_counts:
            r = run_one(backend, n, target)
            results[(backend, n)] = r
            csv_row(f"fig14/{backend}/actors{n}/steps_per_sec",
                    round(r["steps_per_sec"], 1))
            csv_row(f"fig14/{backend}/actors{n}/actor_steps", r["steps"])
            if smoke:
                assert r["steps"] > 0, (
                    f"{backend} backend produced no actor steps")
                assert r["learner_steps"] > 0, (
                    f"{backend} backend: learner never stepped")
    if not smoke:
        for backend in BACKENDS:
            base = results[(backend, actor_counts[0])]["steps_per_sec"]
            top = results[(backend, actor_counts[-1])]["steps_per_sec"]
            csv_row(f"fig14/{backend}/scaling_{actor_counts[-1]}x_vs_1",
                    round(top / max(base, 1e-9), 2),
                    "multi-core hosts: multiprocess should scale; "
                    "1-core CI: documents courier overhead instead")
    return results


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
