"""Fig 11: learning from demonstrations on hard exploration (DeepSea).

Claim: DQfD with optimal-policy demos solves DeepSea where vanilla DQN's
epsilon-greedy exploration does not (success probability 2^-N); on the
stochastic variant more demos (80% successful) are needed."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, run_single_process
from repro.agents.dqfd import DQfDBuilder, DQfDConfig, generate_deep_sea_demos
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.core import make_environment_spec
from repro.envs import DeepSea

SIZE = 8
EPISODES = 300


def main(episodes: int = EPISODES):
    env_factory = lambda s: DeepSea(size=SIZE, seed=1)
    spec = make_environment_spec(env_factory(0))

    dqn = DQNBuilder(spec, DQNConfig(min_replay_size=60, samples_per_insert=0,
                                     batch_size=32, n_step=1, epsilon=0.1),
                     seed=5)
    r_dqn = run_single_process(env_factory, dqn, episodes, seed=5)
    solve_dqn = float(np.mean(np.asarray(r_dqn["returns"][-50:]) > 0.5))

    demos = generate_deep_sea_demos(DeepSea(size=SIZE, seed=1), num_demos=20)
    dqfd = DQfDBuilder(spec, demos,
                       DQfDConfig(min_replay_size=60, samples_per_insert=0,
                                  batch_size=32, n_step=1, demo_ratio=0.5),
                       seed=5)
    r_dqfd = run_single_process(env_factory, dqfd, episodes, seed=5)
    solve_dqfd = float(np.mean(np.asarray(r_dqfd["returns"][-50:]) > 0.5))

    # stochastic deep sea with mixed-quality demos (80/20 per the paper)
    env_factory_s = lambda s: DeepSea(size=SIZE, stochastic=True, seed=1)
    spec_s = make_environment_spec(env_factory_s(0))
    demos_s = generate_deep_sea_demos(
        DeepSea(size=SIZE, stochastic=True, seed=1),
        num_demos=SIZE * 10, success_rate=0.8)
    dqfd_s = DQfDBuilder(spec_s, demos_s,
                         DQfDConfig(min_replay_size=60, samples_per_insert=0,
                                    batch_size=32, n_step=1, demo_ratio=0.5),
                         seed=6)
    r_s = run_single_process(env_factory_s, dqfd_s, episodes, seed=6)
    solve_s = float(np.mean(np.asarray(r_s["returns"][-50:]) > 0.5))

    csv_row("fig11/dqn_solve_rate", round(solve_dqn, 3), f"deep_sea {SIZE}")
    csv_row("fig11/dqfd_solve_rate", round(solve_dqfd, 3),
            "demos unlock exploration")
    csv_row("fig11/dqfd_stochastic_solve_rate", round(solve_s, 3),
            "80/20 mixed demos")
    csv_row("fig11/demos_beat_vanilla", int(solve_dqfd > solve_dqn + 0.2))
    return solve_dqn, solve_dqfd, solve_s


if __name__ == "__main__":
    main()
