"""Fig 10: bsuite-style capability probes.

Radar axes (scaled to our CPU budget): basic (Catch), memory (MemoryChain),
exploration (DeepSea), credit assignment (Bandit).  The paper's headline:
only the recurrent agent (R2D2) scores on memory; MCTS (perfect simulator)
dominates planning-friendly tasks."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, run_single_process
from repro.core import EnvironmentLoop, make_environment_spec
from repro.envs import Bandit, Catch, DeepSea, MemoryChain


def _score(returns, lo, hi):
    m = float(np.mean(returns))
    return max(0.0, min(1.0, (m - lo) / (hi - lo)))


def probe_basic(agent_name, episodes=200):
    spec = make_environment_spec(Catch(seed=0))
    if agent_name == "dqn":
        from repro.agents.dqn import DQNBuilder, DQNConfig
        b = DQNBuilder(spec, DQNConfig(min_replay_size=50,
                                       samples_per_insert=0, batch_size=32,
                                       n_step=1, epsilon=0.2), seed=1)
    elif agent_name == "r2d2":
        from repro.agents.r2d2 import R2D2Builder, R2D2Config
        b = R2D2Builder(spec, R2D2Config(sequence_length=9, period=9,
                                         burn_in=0, batch_size=16,
                                         min_replay_size=60,
                                         samples_per_insert=0, epsilon=0.2),
                        seed=1)
    else:
        from repro.agents.impala import IMPALABuilder, IMPALAConfig
        b = IMPALABuilder(spec, IMPALAConfig(sequence_length=5, batch_size=4,
                                             learning_rate=3e-3), seed=1)
        episodes = episodes * 3
    r = run_single_process(lambda s: Catch(seed=s), b, episodes, seed=1)
    return _score(r["returns"][-40:], -1, 1)


def probe_memory(agent_name, episodes=300):
    env_factory = lambda s: MemoryChain(memory_length=5, seed=s)
    spec = make_environment_spec(env_factory(0))
    if agent_name == "r2d2":
        from repro.agents.r2d2 import R2D2Builder, R2D2Config
        b = R2D2Builder(spec, R2D2Config(sequence_length=6, period=3,
                                         burn_in=0, batch_size=16,
                                         min_replay_size=60,
                                         samples_per_insert=0,
                                         target_update_period=40,
                                         epsilon=0.15), seed=2)
    elif agent_name == "dqn":
        from repro.agents.dqn import DQNBuilder, DQNConfig
        b = DQNBuilder(spec, DQNConfig(min_replay_size=50,
                                       samples_per_insert=0, batch_size=32,
                                       n_step=1, epsilon=0.15), seed=2)
    else:
        return None
    r = run_single_process(env_factory, b, episodes, seed=2)
    return _score(r["returns"][-50:], -1, 1)


def probe_exploration(agent_name, episodes=250):
    env_factory = lambda s: DeepSea(size=6, seed=1)
    spec = make_environment_spec(env_factory(0))
    if agent_name == "dqfd":
        from repro.agents.dqfd import (DQfDBuilder, DQfDConfig,
                                       generate_deep_sea_demos)
        demos = generate_deep_sea_demos(DeepSea(size=6, seed=1), 20)
        b = DQfDBuilder(spec, demos, DQfDConfig(min_replay_size=60,
                                                samples_per_insert=0,
                                                batch_size=32, n_step=1,
                                                demo_ratio=0.5), seed=3)
    else:
        from repro.agents.dqn import DQNBuilder, DQNConfig
        b = DQNBuilder(spec, DQNConfig(min_replay_size=60,
                                       samples_per_insert=0, batch_size=32,
                                       n_step=1, epsilon=0.1), seed=3)
    r = run_single_process(env_factory, b, episodes, seed=3)
    return _score(r["returns"][-50:], -0.05, 0.99)


def probe_credit(agent_name, episodes=400):
    env_factory = lambda s: Bandit(seed=4)
    spec = make_environment_spec(env_factory(0))
    from repro.agents.dqn import DQNBuilder, DQNConfig
    b = DQNBuilder(spec, DQNConfig(min_replay_size=30, samples_per_insert=0,
                                   batch_size=16, n_step=1, epsilon=0.1),
                   seed=4)
    r = run_single_process(env_factory, b, episodes, seed=4)
    return _score(r["returns"][-100:], 0.0, 1.0)


def probe_planning_mcts(episodes=15):
    import jax
    from repro.agents.mcts import MCTSActor, MCTSConfig, make_network
    from repro.core import VariableClient
    from repro.core.variable import VariableServer
    env = Catch(seed=4)
    spec = make_environment_spec(env)
    cfg = MCTSConfig(num_simulations=48, search_depth=12, temperature=0.25)
    init, _, _, _ = make_network(spec, cfg)
    server = VariableServer(policy=init(jax.random.key(0)))
    actor = MCTSActor(spec, cfg, VariableClient(server), model_env=env)
    rets = []
    for _ in range(episodes):
        ts = env.reset()
        total = 0.0
        while not ts.last():
            ts = env.step(actor.select_action(ts.observation))
            total += ts.reward
        rets.append(total)
    return _score(rets, -1, 1)


def main(fast: bool = False):
    k = 0.5 if fast else 1.0
    scores = {}
    scores[("dqn", "basic")] = probe_basic("dqn", int(200 * k))
    scores[("r2d2", "basic")] = probe_basic("r2d2", int(200 * k))
    scores[("dqn", "memory")] = probe_memory("dqn", int(300 * k))
    scores[("r2d2", "memory")] = probe_memory("r2d2", int(300 * k))
    scores[("dqn", "exploration")] = probe_exploration("dqn", int(250 * k))
    scores[("dqfd", "exploration")] = probe_exploration("dqfd", int(250 * k))
    scores[("dqn", "credit")] = probe_credit("dqn", int(400 * k))
    scores[("mcts", "planning")] = probe_planning_mcts(10)
    for (agent, axis), s in scores.items():
        csv_row(f"fig10/{agent}/{axis}", round(s, 3), "0..1 radar score")
    csv_row("fig10/memory_needs_recurrence",
            int(scores[("r2d2", "memory")] > scores[("dqn", "memory")] + 0.1))
    return scores


if __name__ == "__main__":
    main()
