"""Fig 9: discrete-action agents (DQN, R2D2, IMPALA) compared on the same
task — the paper's qualitative claim: feed-forward DQN gets off the ground
fast; R2D2 is slower but strong; IMPALA learns quickly but can be unstable."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, curve_summary, run_single_process
from repro.core import make_environment_spec
from repro.envs import Catch

EPISODES = {"dqn": 200, "r2d2": 300, "impala": 600}


def main(scale: float = 1.0):
    spec = make_environment_spec(Catch(seed=0))
    finals = {}

    from repro.agents.dqn import DQNBuilder, DQNConfig
    b = DQNBuilder(spec, DQNConfig(min_replay_size=50, samples_per_insert=0,
                                   batch_size=32, n_step=1, epsilon=0.2), seed=1)
    r = run_single_process(lambda s: Catch(seed=s), b,
                           int(EPISODES["dqn"] * scale), seed=1)
    finals["dqn"] = curve_summary("fig9/dqn", r)

    from repro.agents.r2d2 import R2D2Builder, R2D2Config
    # period < length: overlap so terminal rewards appear at non-final
    # sequence indices (the within-sequence TD loss drops the last slot)
    cfg = R2D2Config(sequence_length=9, period=5, burn_in=0, batch_size=16,
                     min_replay_size=60, samples_per_insert=0,
                     target_update_period=50, epsilon=0.2)
    b = R2D2Builder(spec, cfg, seed=2)
    r = run_single_process(lambda s: Catch(seed=s), b,
                           int(EPISODES["r2d2"] * scale), seed=2)
    finals["r2d2"] = curve_summary("fig9/r2d2", r)

    from repro.agents.impala import IMPALABuilder, IMPALAConfig
    cfg = IMPALAConfig(sequence_length=5, batch_size=4, learning_rate=3e-3,
                       entropy_cost=0.02)
    b = IMPALABuilder(spec, cfg, seed=3)
    r = run_single_process(lambda s: Catch(seed=s), b,
                           int(EPISODES["impala"] * scale), seed=3)
    finals["impala"] = curve_summary("fig9/impala", r)

    csv_row("fig9/all_improve", int(all(v > -0.4 for v in finals.values())))
    return finals


if __name__ == "__main__":
    main()
