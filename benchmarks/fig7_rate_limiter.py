"""Fig 7/18: rate-limiter (samples-per-insert) sensitivity.

Paper claim: low SPI is wasteful (more env interactions to the same return);
over-high SPI destabilizes.  We sweep SPI on synchronous DQN/Catch where the
SPI maps to learner-steps-per-observation, and report sample efficiency
(episodes to reach a return threshold) per SPI."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, run_single_process, smooth
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.core import make_environment_spec
from repro.envs import Catch

SPIS = (0.5, 4.0, 32.0)
EPISODES = 250
THRESHOLD = 0.3


def episodes_to_threshold(returns, threshold=THRESHOLD, k=25):
    sm = smooth(returns, k)
    hits = np.where(sm >= threshold)[0]
    return int(hits[0]) + k if len(hits) else -1


def main(episodes: int = EPISODES):
    spec = make_environment_spec(Catch(seed=0))
    results = {}
    for spi in SPIS:
        # synchronous proxy: batch_size/spi observations per learner step
        cfg = DQNConfig(min_replay_size=100, samples_per_insert=spi,
                        batch_size=32, n_step=1, epsilon=0.15)
        builder = DQNBuilder(spec, cfg, seed=4)
        result = run_single_process(lambda s: Catch(seed=s), builder,
                                    episodes, seed=4)
        e2t = episodes_to_threshold(result["returns"])
        final = float(np.mean(result["returns"][-30:]))
        results[spi] = (e2t, final)
        csv_row(f"fig7/spi{spi}/episodes_to_{THRESHOLD}", e2t,
                "-1 = never reached")
        csv_row(f"fig7/spi{spi}/final_return", round(final, 3))
        csv_row(f"fig7/spi{spi}/learner_steps", result["learner_steps"])
    # claim: higher SPI reaches threshold in fewer (or equal) episodes
    lo, hi = results[SPIS[0]], results[SPIS[-1]]
    ok = (lo[0] == -1 and hi[0] != -1) or (hi[0] != -1 and hi[0] <= lo[0])
    csv_row("fig7/low_spi_is_wasteful", int(ok),
            f"spi{SPIS[0]} e2t={lo[0]} vs spi{SPIS[-1]} e2t={hi[0]}")
    return results


if __name__ == "__main__":
    main()
