"""Kernel micro-benchmarks: interpret-mode correctness timing plus the
XLA-path equivalents (the numbers that matter on CPU are the ref paths; the
Pallas paths are TPU-target and here only verified + timed for regression
tracking)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.kernels import ops, ref


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def main():
    rng = np.random.RandomState(0)
    # flash attention ref (XLA path used by the model zoo)
    q = jnp.asarray(rng.randn(1, 4, 512, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 4, 512, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 4, 512, 64), jnp.float32)
    us = _time(jax.jit(lambda *a: ref.flash_attention_ref(*a)), q, k, v)
    csv_row("kernels/flash_ref_xla_512", round(us, 1), "us_per_call")

    qd = jnp.asarray(rng.randn(2, 8, 64), jnp.float32)
    kd = jnp.asarray(rng.randn(2, 2048, 8, 64), jnp.float32)
    vd = jnp.asarray(rng.randn(2, 2048, 8, 64), jnp.float32)
    lens = jnp.asarray([2048, 1024], jnp.int32)
    us = _time(jax.jit(lambda *a: ref.decode_attention_ref(*a)), qd, kd, vd, lens)
    csv_row("kernels/decode_ref_xla_2k", round(us, 1), "us_per_call")

    x = jnp.asarray(rng.randn(1, 1024, 4, 64), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(1, 1024, 4)) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(rng.randn(4)) + 0.5, jnp.float32)
    B = jnp.asarray(rng.randn(1, 1024, 64), jnp.float32)
    C = jnp.asarray(rng.randn(1, 1024, 64), jnp.float32)
    us = _time(jax.jit(lambda *a: ref.ssd_scan_ref(*a, 256)[0]), x, dt, A, B, C)
    csv_row("kernels/ssd_ref_xla_1k", round(us, 1), "us_per_call")

    T, Bt = 128, 256
    args = [jnp.asarray(rng.randn(T, Bt), jnp.float32) for _ in range(2)] + \
        [jnp.asarray(rng.randn(T, Bt), jnp.float32),
         jnp.asarray(rng.rand(T, Bt) * 0.99, jnp.float32),
         jnp.asarray(np.abs(rng.randn(T, Bt)), jnp.float32)]
    us = _time(jax.jit(lambda *a: ref.vtrace_ref(*a)[0]), *args)
    csv_row("kernels/vtrace_ref_xla_128x256", round(us, 1), "us_per_call")

    # interpret-mode allclose spot checks (slow; tiny shapes)
    out = ops.flash_attention(q[:, :1, :128], k[:, :1, :128], v[:, :1, :128],
                              interpret=True)
    exp = ref.flash_attention_ref(q[:, :1, :128], k[:, :1, :128], v[:, :1, :128])
    csv_row("kernels/flash_pallas_allclose",
            int(float(jnp.max(jnp.abs(out - exp))) < 1e-4))
    return True


if __name__ == "__main__":
    main()
