"""Fig 16: learner scaling — aggregate SGD throughput vs learner replicas.

The multi-learner half of the §2.4 scaling story: ``num_learner_replicas=N``
places one learner replica per replay shard (shard-affine datasets, so no
two replicas contend on one table lock) with a ``ParameterServer`` merging
params/opt-state every ``learner_average_period`` steps.  This figure
sweeps the replica count through the UNCHANGED ``DQNBuilder`` and reports
aggregate learner steps/sec (summed over replicas) plus averaging rounds.

What to expect: each replica is its own SGD stream over its own shard, so
aggregate throughput scales until cores run out — on a 1-core CI container
the replicas time-share the interpreter and the figure instead documents
the averaging overhead (a barrier + pytree mean every period).  The honest
caveat either way: N replicas averaging every P steps is NOT N× the
gradient quality of one stream; the figure reports throughput, the
learning-quality evidence lives in ``tests/test_multi_learner.py``.

    python benchmarks/fig16_learner_scaling.py            # full sweep
    python benchmarks/fig16_learner_scaling.py --smoke    # CI mechanics check
"""
from __future__ import annotations

import sys
import time

from benchmarks.common import csv_row
from repro.agents.builders import make_distributed_agent
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.core import make_environment_spec
from repro.envs import Catch

REPLICA_COUNTS = (1, 2, 4)
AVERAGE_PERIOD = 20
# The stop criterion is aggregate SGD steps, not actor steps: the figure
# measures learner throughput, and an actor-step target races the first
# jit compile on fast hosts (the run can end before a replica ever steps).
TARGET_SGD_STEPS = 2000
SMOKE_TARGET_SGD_STEPS = 80
TIMEOUT_S = 180.0


# Module-level factories: picklable for process-crossing backends.
def builder_factory(spec):
    # samples_per_insert=0 -> MinSize limiter: replicas step unthrottled,
    # so the figure measures SGD throughput, not the SPI schedule.  A low
    # replay floor lets replicas start stepping (and finish their first
    # jit compile) well inside a short smoke window.
    return DQNBuilder(spec, DQNConfig(min_replay_size=32,
                                      samples_per_insert=0.0,
                                      batch_size=16, n_step=1), seed=0)


def env_factory(seed):
    return Catch(seed=seed)


def run_one(num_replicas: int, target_sgd_steps: int, average_period: int):
    spec = make_environment_spec(env_factory(0))
    builder = builder_factory(spec)
    dist = make_distributed_agent(
        builder, env_factory, num_actors=2, seed=0,
        builder_factory=builder_factory,
        num_learner_replicas=num_replicas,
        learner_average_period=average_period)
    t0 = time.time()
    try:
        while time.time() - t0 < TIMEOUT_S:
            stats = dist.learner_stats()
            if sum(stats["per_replica_steps"]) >= target_sgd_steps:
                break
            time.sleep(0.1)
        stats = dist.learner_stats()
        wall = time.time() - t0
    finally:
        dist.stop()
    total_sgd = sum(stats["per_replica_steps"])
    return {"total_sgd": total_sgd, "wall": wall,
            "sgd_per_sec": total_sgd / max(wall, 1e-9),
            "rounds": stats["rounds"],
            "per_replica": stats["per_replica_steps"]}


def main(smoke: bool = False):
    target = SMOKE_TARGET_SGD_STEPS if smoke else TARGET_SGD_STEPS
    replica_counts = (1, 2) if smoke else REPLICA_COUNTS
    results = {}
    for n in replica_counts:
        r = run_one(n, target, AVERAGE_PERIOD)
        results[n] = r
        csv_row(f"fig16/replicas{n}/sgd_steps_per_sec",
                round(r["sgd_per_sec"], 1))
        csv_row(f"fig16/replicas{n}/total_sgd_steps", r["total_sgd"])
        csv_row(f"fig16/replicas{n}/averaging_rounds", r["rounds"])
        if smoke:
            assert r["total_sgd"] > 0, (
                f"{n} replica(s): learner never stepped")
            assert all(s > 0 for s in r["per_replica"]), (
                f"{n} replica(s): a replica never stepped: {r}")
            if n > 1:
                assert r["rounds"] >= 1, (
                    f"{n} replicas never completed an averaging round: {r}")
    if smoke:
        print("fig16 smoke OK:", {n: r["per_replica"]
                                  for n, r in results.items()})
    return results


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
