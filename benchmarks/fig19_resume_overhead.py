"""Fig 19: exact-resume checkpoint overhead — run-wide snapshot write and
restore latency as the replay table grows.

A ``RunCheckpointer`` save is dominated by pickling replay *contents*
(items + selector internals); the learner npz is a constant few hundred
KB.  This figure prices one save+restore round trip at several replay
fills against the same DQN-on-Catch learner state, reporting latency and
on-disk size per component — the number a user trades against
``checkpoint_every`` when tuning resume granularity.

The restore leg also re-verifies the bit-exactness foundation at every
size: the restored table must continue the EXACT sample stream of the
original (selector array + RNG round-trip), not merely hold the same
items.

    python benchmarks/fig19_resume_overhead.py            # full sweep
    python benchmarks/fig19_resume_overhead.py --smoke    # CI check
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import csv_row
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.core import make_environment_spec
from repro.envs import Catch
from repro.replay import MinSize, Prioritized, Table
from repro.resilience import RunCheckpointer

SIZES = (1_000, 5_000, 20_000)
SMOKE_SIZES = (500, 2_000)
# generous: CI hosts are noisy, and the point of the smoke tier is the
# mechanics (write protocol, manifest, sample-stream parity), not speed
SMOKE_ROUNDTRIP_CEILING_S = 20.0


def _learner_state():
    spec = make_environment_spec(Catch(seed=0))
    builder = DQNBuilder(spec, DQNConfig(min_replay_size=10,
                                         samples_per_insert=0.0,
                                         batch_size=16, n_step=1), seed=0)
    learner = builder.make_learner(builder.make_dataset(builder.make_replay()))
    return learner.state


def _make_table(capacity: int) -> Table:
    # prioritized selector: the sum-tree array is the expensive selector
    # state, so this is the worst case per item.  Size the tree to the
    # table (the default 1<<20 would put a constant 16MB in every file
    # and flatten the scaling curve).
    return Table("bench", capacity,
                 Prioritized(priority_exponent=0.6, capacity=capacity,
                             seed=1),
                 MinSize(1))


def _fill(table: Table, n: int):
    rng = np.random.RandomState(0)
    for _ in range(n):
        transition = (rng.rand(10, 5).astype(np.float32),
                      int(rng.randint(3)), float(rng.rand()), 1.0,
                      rng.rand(10, 5).astype(np.float32))
        table.insert(transition, priority=float(rng.rand()) + 0.1)


def _component_bytes(directory: str, step: int) -> dict:
    sizes = {}
    for f in os.listdir(directory):
        if f.endswith(f"_{step}.pkl") or f.endswith(f"_{step}.npz"):
            sizes[f.split("_")[0]] = os.path.getsize(
                os.path.join(directory, f))
    return sizes


def measure_one(state, n: int) -> dict:
    table = _make_table(n + 16)
    _fill(table, n)
    directory = tempfile.mkdtemp(prefix="fig19_")
    try:
        ck = RunCheckpointer(directory)
        t0 = time.monotonic()
        ck.save(n, state, replay=table.state_dict(),
                counts={"actor_steps": float(n)},
                meta={"mode": "benchmark"})
        save_s = time.monotonic() - t0
        parts = _component_bytes(directory, n)

        t0 = time.monotonic()
        snapshot = RunCheckpointer(directory).restore(state)
        restored = _make_table(n + 16)
        restored.load_state_dict(snapshot.replay)
        restore_s = time.monotonic() - t0

        # bit-exactness foundation: identical subsequent sample streams
        for _ in range(5):
            a = [(it.key, prob) for it, prob in table.sample(4)]
            b = [(it.key, prob) for it, prob in restored.sample(4)]
            assert a == b, f"sample stream diverged after restore (n={n})"
        assert snapshot.counts == {"actor_steps": float(n)}
        return {"save_s": save_s, "restore_s": restore_s,
                "replay_mb": parts.get("replay", 0) / 1e6,
                "learner_mb": parts.get("learner", 0) / 1e6}
    finally:
        shutil.rmtree(directory, ignore_errors=True)


def main(smoke: bool = False):
    sizes = SMOKE_SIZES if smoke else SIZES
    state = _learner_state()
    results = {}
    for n in sizes:
        r = measure_one(state, n)
        results[n] = r
        csv_row(f"fig19/replay_{n}/save_ms", round(r["save_s"] * 1000, 1))
        csv_row(f"fig19/replay_{n}/restore_ms",
                round(r["restore_s"] * 1000, 1))
        csv_row(f"fig19/replay_{n}/replay_mb", round(r["replay_mb"], 2))
        csv_row(f"fig19/replay_{n}/learner_mb", round(r["learner_mb"], 2))
    if smoke:
        worst = max(r["save_s"] + r["restore_s"] for r in results.values())
        assert worst < SMOKE_ROUNDTRIP_CEILING_S, (
            f"checkpoint round trip took {worst:.1f}s — above the "
            f"{SMOKE_ROUNDTRIP_CEILING_S}s smoke ceiling")
        print(f"fig19 smoke OK: worst round trip {worst * 1000:.0f}ms "
              f"across replay sizes {list(sizes)}")
    return results


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
