#!/usr/bin/env bash
# Two-tier reproducible CI:
#
#   tier 1 (fast, every push): deps + `pytest -m "not slow"` — includes the
#       multi-learner parity net, so averaging regressions surface on every
#       run without paying for the multiprocess smokes.
#   slow tier: `pytest -m slow` (multiprocess learning smokes) + the
#       benchmark --smoke mechanics checks.
#
#   bash scripts/ci.sh                 # both tiers
#   SKIP_TESTS=1 bash scripts/ci.sh    # benchmarks + script smokes only
#   SKIP_SLOW=1 bash scripts/ci.sh    # fast tier only
set -euo pipefail
cd "$(dirname "$0")/.."

# Deps are baked into the container image; install is best-effort so the
# script also works offline.
python -m pip install -q -r requirements.txt -r requirements-dev.txt \
    || echo "[ci] pip install skipped (offline?) — using preinstalled deps"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ -z "${SKIP_TESTS:-}" ]]; then
    echo "[ci] tier-1 (fast): python -m pytest -q -m 'not slow'"
    python -m pytest -q -m "not slow"
fi

if [[ -n "${SKIP_SLOW:-}" ]]; then
    echo "[ci] SKIP_SLOW set — fast tier only"
    exit 0
fi

if [[ -z "${SKIP_TESTS:-}" ]]; then
    echo "[ci] slow tier: python -m pytest -q -m slow"
    python -m pytest -q -m slow
fi

echo "[ci] smoke: replay sharding throughput (fig13 --smoke)"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/fig13_replay_sharding.py --smoke

echo "[ci] smoke: actor scaling, local + multiprocess backends (fig14 --smoke)"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/fig14_actor_scaling.py --smoke

echo "[ci] smoke: vectorized acting + inference batching (fig15 --smoke)"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/fig15_inference_batching.py --smoke

echo "[ci] smoke: multi-learner replica scaling (fig16 --smoke)"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/fig16_learner_scaling.py --smoke

echo "[ci] smoke: transformer policy serving (fig17 --smoke)"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/fig17_transformer_serving.py --smoke

echo "[ci] smoke: telemetry overhead (fig18 --smoke)"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/fig18_telemetry_overhead.py --smoke

echo "[ci] smoke: exact-resume checkpoint overhead (fig19 --smoke)"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/fig19_resume_overhead.py --smoke

echo "[ci] smoke: async vs barrier learner throughput (fig20 --smoke)"
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/fig20_async_learner.py --smoke

echo "[ci] smoke: multiprocess launcher — DQN on Catch over courier RPC"
# a real file, not a stdin heredoc: spawn children re-import __main__
python scripts/smoke_multiprocess.py

echo "[ci] smoke: chaos harness — actor kill + elastic respawn"
python scripts/smoke_chaos.py

echo "[ci] smoke: chaos harness — replay-shard kill + service failover"
python scripts/smoke_chaos.py --target replay/shard_0

echo "[ci] smoke: DQN on Catch via repro.experiments.run_experiment"
python - <<'EOF'
import time

import numpy as np

from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.envs import Catch
from repro.experiments import ExperimentConfig, run_experiment

t0 = time.time()
config = ExperimentConfig(
    builder_factory=lambda spec: DQNBuilder(
        spec, DQNConfig(min_replay_size=50, samples_per_insert=0.0,
                        batch_size=32, n_step=1, epsilon=0.2), seed=0),
    environment_factory=lambda seed: Catch(seed=seed),
    seed=0, num_episodes=150, eval_episodes=20)
result = run_experiment(config)
final = result.final_eval_return
print(f"[ci] smoke: {result.learner_steps} learner steps, "
      f"eval return {final:+.2f}, {time.time() - t0:.0f}s")
assert result.learner_steps > 0, "learner never stepped"
assert final is not None and final > np.mean(result.train_returns[:20]), \
    "smoke run did not improve over early training returns"
print("[ci] OK")
EOF
