"""CI smoke: the chaos harness kills a node mid-run and the run absorbs it
(repro.resilience).

Default mode (no ``--target``): a seeded ``ChaosPolicy`` hard-kills
``actor/0`` after 150 environment steps (``os._exit`` — the same failure
surface as an OOM kill); the ``MultiprocessLauncher`` classifies the death
as a crash, respawns the replica under its ``RestartPolicy`` budget, and
the respawned worker — seeing ``REPRO_WORKER_RESTARTS`` — disarms its kill
schedule and trains to the step target.

``--target <service>`` mode (e.g. ``--target replay/shard_0``): the kill
lands on a ``role="service"`` node instead.  The ``ServiceWatchdog``
simulates the death (mark_down + courier-server teardown), restores the
service from its last periodic snapshot, and re-binds its server at the
same address; actor workers absorb the outage (reconnect or skipped adds)
and the run still reaches the step target.

A real file (not a stdin heredoc) because the spawn context re-imports
``__main__`` in every child.
"""
import argparse
import time

from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.envs import Catch
from repro.experiments import ExperimentConfig, run_distributed_experiment
from repro.resilience import ChaosPolicy, RestartPolicy


def builder_factory(spec):
    return DQNBuilder(spec, DQNConfig(min_replay_size=50,
                                      samples_per_insert=4.0,
                                      batch_size=16, n_step=1), seed=0)


def env_factory(seed):
    return Catch(seed=seed)


def _run_worker_chaos():
    t0 = time.time()
    config = ExperimentConfig(
        builder_factory=builder_factory,
        environment_factory=env_factory,
        seed=0, eval_episodes=0, launcher="multiprocess",
        restart_policy=RestartPolicy(max_restarts=3),
        chaos=ChaosPolicy(kill_after_steps=150, kill_targets=("actor/0",),
                          max_kills=1))
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=1500, timeout_s=180)
    steps = int(result.counts.get("actor_steps", 0))
    resilience = result.extras["resilience"]
    print(f"[ci] chaos smoke: {steps} actor steps, "
          f"{result.learner_steps} learner steps, "
          f"restarts {resilience['restarts']}, "
          f"exit kinds {resilience['exit_kinds']}, "
          f"{time.time() - t0:.0f}s")
    assert steps >= 1500, "run never reached the step target through chaos"
    assert result.learner_steps > 0, "learner never stepped"
    assert resilience["restarts"].get("actor/0") == 1, (
        f"the killed actor was not respawned exactly once: {resilience}")
    assert "crash" in resilience["exit_kinds"]["actor/0"], resilience


def _run_service_chaos(target: str):
    t0 = time.time()
    config = ExperimentConfig(
        builder_factory=builder_factory,
        environment_factory=env_factory,
        seed=0, eval_episodes=0, launcher="multiprocess",
        num_replay_shards=2,
        restart_policy=RestartPolicy(max_restarts=3),
        chaos=ChaosPolicy(kill_after_steps=200, kill_targets=(target,),
                          max_kills=1))
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=1500, timeout_s=180)
    steps = int(result.counts.get("actor_steps", 0))
    resilience = result.extras["resilience"]
    print(f"[ci] service chaos smoke ({target}): {steps} actor steps, "
          f"{result.learner_steps} learner steps, "
          f"service restarts {resilience['service_restarts']}, "
          f"service exit kinds {resilience['service_exit_kinds']}, "
          f"worker restarts {resilience['restarts']}, "
          f"{time.time() - t0:.0f}s")
    assert steps >= 1500, "run never reached the step target through chaos"
    assert result.learner_steps > 0, "learner never stepped"
    assert resilience["service_restarts"].get(target) == 1, (
        f"the killed service was not restored exactly once: {resilience}")
    assert "crash" in resilience["service_exit_kinds"][target], resilience
    assert resilience["restarts"] == {}, (
        f"a worker died during the service outage: {resilience}")


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--target", default=None,
        help="service node to kill (e.g. replay/shard_0) instead of the "
             "default actor/0 worker kill")
    args = parser.parse_args()
    if args.target is None:
        _run_worker_chaos()
    else:
        _run_service_chaos(args.target)


if __name__ == "__main__":
    main()
