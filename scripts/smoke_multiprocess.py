"""CI smoke: the multiprocess launcher trains DQN on Catch over courier.

A real file (not a stdin heredoc) because the spawn context re-imports
``__main__`` in every child — factories must live at module level and the
driver must be guarded by ``__name__ == "__main__"``.
"""
import time

from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.envs import Catch
from repro.experiments import ExperimentConfig, run_distributed_experiment


def builder_factory(spec):
    return DQNBuilder(spec, DQNConfig(min_replay_size=50,
                                      samples_per_insert=4.0,
                                      batch_size=16, n_step=1), seed=0)


def env_factory(seed):
    return Catch(seed=seed)


def main():
    t0 = time.time()
    config = ExperimentConfig(builder_factory=builder_factory,
                              environment_factory=env_factory,
                              seed=0, eval_episodes=0,
                              launcher="multiprocess")
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=1500, timeout_s=180)
    steps = int(result.counts.get("actor_steps", 0))
    print(f"[ci] multiprocess smoke: {steps} actor steps across 2 "
          f"processes, {result.learner_steps} learner steps, "
          f"spi {result.extras['spi_effective']:.2f}, "
          f"{time.time() - t0:.0f}s")
    assert steps >= 1500, "actor processes never reached the step target"
    assert result.learner_steps > 0, "learner never stepped"


if __name__ == "__main__":
    main()
