"""End-to-end continuous-control driver: D4PG (distributional critic) on
pendulum swingup from raw features — the paper's Fig 5 workhorse, run
through the experiments API.

  PYTHONPATH=src python examples/train_d4pg_pendulum.py
"""
import numpy as np

from repro.agents.continuous import ContinuousBuilder, ContinuousConfig
from repro.envs import PendulumSwingup
from repro.experiments import ExperimentConfig, run_experiment

EPISODE_LEN = 150


def main():
    cfg = ContinuousConfig(algo="d4pg", hidden=64, batch_size=64,
                           min_replay_size=300, samples_per_insert=0.0,
                           n_step=3, sigma=0.3, vmin=0.0,
                           vmax=float(EPISODE_LEN), num_atoms=31,
                           target_update_period=50)
    config = ExperimentConfig(
        builder_factory=lambda spec: ContinuousBuilder(spec, cfg, seed=2),
        environment_factory=lambda seed: PendulumSwingup(
            seed=seed, episode_len=EPISODE_LEN),
        seed=1,
        num_episodes=80,
        eval_every=20,
        eval_episodes=5,
    )
    result = run_experiment(config)

    rets = result.train_returns
    for ep in range(9, len(rets), 10):
        print(f"episode {ep + 1:3d}  return {rets[ep]:6.1f}  "
              f"avg10 {np.mean(rets[max(ep - 9, 0):ep + 1]):6.1f} "
              f"/ {EPISODE_LEN}")
    print(f"final eval return: {result.final_eval_return:6.1f}")
    print("done; learner steps:", result.learner_steps)


if __name__ == "__main__":
    main()
