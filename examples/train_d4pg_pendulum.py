"""End-to-end continuous-control driver: D4PG (distributional critic) on
pendulum swingup from raw features — the paper's Fig 5 workhorse.

  PYTHONPATH=src python examples/train_d4pg_pendulum.py
"""
import numpy as np

from repro.agents.builders import make_agent
from repro.agents.continuous import ContinuousBuilder, ContinuousConfig
from repro.core import EnvironmentLoop, make_environment_spec
from repro.envs import PendulumSwingup

EPISODE_LEN = 150


def main():
    env = PendulumSwingup(seed=1, episode_len=EPISODE_LEN)
    spec = make_environment_spec(env)
    cfg = ContinuousConfig(algo="d4pg", hidden=64, batch_size=64,
                           min_replay_size=300, samples_per_insert=0.0,
                           n_step=3, sigma=0.3, vmin=0.0,
                           vmax=float(EPISODE_LEN), num_atoms=31,
                           target_update_period=50)
    agent = make_agent(ContinuousBuilder(spec, cfg, seed=2))
    loop = EnvironmentLoop(env, agent)
    rets = []
    for ep in range(80):
        rets.append(loop.run_episode()["episode_return"])
        if (ep + 1) % 10 == 0:
            print(f"episode {ep+1:3d}  return {rets[-1]:6.1f}  "
                  f"avg10 {np.mean(rets[-10:]):6.1f} / {EPISODE_LEN}")
    print("done; learner steps:", int(agent.learner.state.steps))


if __name__ == "__main__":
    main()
