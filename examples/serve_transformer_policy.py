"""Batched transformer-policy serving (SEED-RL style actor inference):
load a reduced architecture from the assigned pool, prefill a prompt batch,
then decode tokens step-by-step through the KV/SSM cache — the same
``serve_step`` the multi-pod dry-run lowers for decode_32k / long_500k.

  PYTHONPATH=src python examples/serve_transformer_policy.py --arch qwen3-1.7b
  PYTHONPATH=src python examples/serve_transformer_policy.py --arch mamba2-780m
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.launch.steps import make_batched_prefill_step, make_serve_step
from repro.models import transformer


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b", choices=sorted(ARCHS))
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--decode-len", type=int, default=32)
    args = p.parse_args()

    cfg = reduced(ARCHS[args.arch])       # CPU-sized variant of the family
    print(f"arch={cfg.name} ({cfg.arch_type}) reduced to "
          f"{cfg.num_layers}L d{cfg.d_model}")
    rng = jax.random.key(0)
    params = transformer.init(rng, cfg, jnp.float32)

    b = args.batch
    max_len = args.prompt_len + args.decode_len
    cache = transformer.init_cache(cfg, b, max_len, jnp.float32)
    serve = jax.jit(make_serve_step(cfg))

    prompt = jax.random.randint(rng, (b, args.prompt_len), 0, cfg.vocab_size)
    if cfg.arch_type in ("dense", "moe"):
        # whole-prompt prefill through the cache in one jitted call
        prefill = jax.jit(make_batched_prefill_step(cfg))
        tok, logits, cache = prefill(params, cache, prompt)
    else:
        # ssm/hybrid/audio caches: step the decoder over the prompt
        for t in range(args.prompt_len):
            tok, logits, cache = serve(params, cache, prompt[:, t:t + 1],
                                       jnp.int32(t))
    # decode
    t0 = time.time()
    out = [tok]
    for i in range(args.decode_len - 1):
        tok, logits, cache = serve(params, cache, tok,
                                   jnp.int32(args.prompt_len + i))
        out.append(tok)
    dt = time.time() - t0
    tokens = jnp.concatenate(out, axis=1)
    print(f"decoded {tokens.shape} in {dt:.2f}s "
          f"({b * (args.decode_len - 1) / dt:.0f} tok/s on CPU)")
    print("sample:", tokens[0, :16].tolist())


if __name__ == "__main__":
    main()
