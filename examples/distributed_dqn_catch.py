"""Distributed agent (Fig 4 of the paper): N actor nodes + a learner node +
a rate-limited replay table, launched on a Launchpad-lite program graph —
from the SAME ExperimentConfig a single-process run would use.

  PYTHONPATH=src python examples/distributed_dqn_catch.py --actors 4
"""
import argparse

from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.envs import Catch
from repro.experiments import ExperimentConfig, run_distributed_experiment


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--actors", type=int, default=4)
    p.add_argument("--actor-steps", type=int, default=6000)
    args = p.parse_args()

    cfg = DQNConfig(min_replay_size=100, samples_per_insert=8.0,
                    batch_size=32, n_step=1, epsilon=0.15)
    config = ExperimentConfig(
        builder_factory=lambda spec: DQNBuilder(spec, cfg, seed=0),
        environment_factory=lambda seed: Catch(seed=seed),
        seed=0,
        max_actor_steps=args.actor_steps,
        eval_episodes=30,
    )
    print(f"launching: {args.actors} actors + learner + replay "
          f"(SPI target {cfg.samples_per_insert})")
    result = run_distributed_experiment(config, num_actors=args.actors,
                                        timeout_s=300)

    ex = result.extras
    print(f"actor_steps={result.counts.get('actor_steps', 0):6.0f} "
          f"learner_steps={result.learner_steps:5d} "
          f"inserts={ex['inserts']} samples={ex['samples']} "
          f"spi_effective={ex['spi_effective']:.1f}")
    print(f"eval return over 30 episodes: {result.final_eval_return:+.2f}")


if __name__ == "__main__":
    main()
