"""Distributed agent (Fig 4 of the paper): N actor nodes + a learner node +
a rate-limited replay service, launched on a Launchpad-lite program graph —
from the SAME ExperimentConfig a single-process run would use.  The
execution backend is a config field: ``--launcher multiprocess`` places each
actor in its own OS process with courier RPC edges, no other change.

  PYTHONPATH=src python examples/distributed_dqn_catch.py --actors 4
  PYTHONPATH=src python examples/distributed_dqn_catch.py \
      --actors 4 --replay-shards 4 --prefetch 4   # sharded replay service
  PYTHONPATH=src python examples/distributed_dqn_catch.py \
      --actors 4 --launcher multiprocess          # one process per actor
  PYTHONPATH=src python examples/distributed_dqn_catch.py \
      --actors 4 --learner-replicas 2             # one learner per shard,
                                                  # parameter averaging

Factories are module-level (not lambdas): process-crossing backends pickle
them into the spawned actor processes.
"""
import argparse
import functools

from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.envs import Catch
from repro.experiments import ExperimentConfig, run_distributed_experiment


def make_builder(spec, cfg: DQNConfig):
    return DQNBuilder(spec, cfg, seed=0)


def make_env(seed: int):
    return Catch(seed=seed)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--actors", type=int, default=4)
    p.add_argument("--actor-steps", type=int, default=6000)
    p.add_argument("--replay-shards", type=int, default=1,
                   help="replay shards (one replay node per shard)")
    p.add_argument("--prefetch", type=int, default=0,
                   help="learner prefetch queue depth in batches")
    p.add_argument("--launcher", default="local",
                   choices=["local", "multiprocess"],
                   help="execution backend: threads, or one OS process "
                        "per actor with courier RPC edges")
    p.add_argument("--learner-replicas", type=int, default=None,
                   help="learner replicas, one per replay shard, merged by "
                        "parameter averaging (actors still see one logical "
                        "learner)")
    p.add_argument("--average-period", type=int, default=None,
                   help="per-replica SGD steps between averaging rounds")
    args = p.parse_args()

    cfg = DQNConfig(min_replay_size=100, samples_per_insert=8.0,
                    batch_size=32, n_step=1, epsilon=0.15)
    config = ExperimentConfig(
        builder_factory=functools.partial(make_builder, cfg=cfg),
        environment_factory=make_env,
        seed=0,
        max_actor_steps=args.actor_steps,
        eval_episodes=30,
        num_replay_shards=args.replay_shards,
        prefetch_size=args.prefetch,
        launcher=args.launcher,
        num_learner_replicas=args.learner_replicas,
        learner_average_period=args.average_period,
    )
    print(f"launching [{args.launcher}]: {args.actors} actors + learner "
          f"+ replay[{args.replay_shards} shard(s)] "
          f"(SPI target {cfg.samples_per_insert}, "
          f"prefetch {args.prefetch})")
    result = run_distributed_experiment(config, num_actors=args.actors,
                                        timeout_s=300)

    ex = result.extras
    print(f"actor_steps={result.counts.get('actor_steps', 0):6.0f} "
          f"learner_steps={result.learner_steps:5d} "
          f"inserts={ex['inserts']} samples={ex['samples']} "
          f"spi_effective={ex['spi_effective']:.1f}")
    print(f"eval return over 30 episodes: {result.final_eval_return:+.2f}")
    if "replay" in ex:
        for shard in ex["replay"]["per_shard"]:
            print(f"  {shard['name']}: size={shard['size']} "
                  f"inserts={shard['inserts']} samples={shard['samples']}")
    if "learners" in ex:
        lrn = ex["learners"]
        print(f"  learners: {lrn['num_replicas']} replica(s), "
              f"{lrn['rounds']} averaging round(s) "
              f"(period {lrn['average_period']}), per-replica steps "
              f"{lrn['per_replica_steps']}")


if __name__ == "__main__":
    main()
