"""Distributed agent (Fig 4 of the paper): N actor nodes + a learner node +
a rate-limited replay table, launched on a Launchpad-lite program graph.

  PYTHONPATH=src python examples/distributed_dqn_catch.py --actors 4
"""
import argparse
import time

import numpy as np

from repro.agents.builders import make_distributed_agent
from repro.agents.dqn import DQNBuilder, DQNConfig, make_eval_policy
from repro.core import EnvironmentLoop, FeedForwardActor, VariableClient, make_environment_spec
from repro.envs import Catch


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--actors", type=int, default=4)
    p.add_argument("--actor-steps", type=int, default=6000)
    args = p.parse_args()

    spec = make_environment_spec(Catch(seed=0))
    cfg = DQNConfig(min_replay_size=100, samples_per_insert=8.0,
                    batch_size=32, n_step=1, epsilon=0.15)
    builder = DQNBuilder(spec, cfg, seed=0)

    dist = make_distributed_agent(builder, lambda s: Catch(seed=s),
                                  num_actors=args.actors)
    print(f"launched: {args.actors} actors + learner + replay "
          f"(SPI target {cfg.samples_per_insert})")
    try:
        t0 = time.time()
        while True:
            counts = dist.counter.get_counts()
            steps = counts.get("actor_steps", 0)
            if steps >= args.actor_steps or time.time() - t0 > 300:
                break
            time.sleep(1.0)
            rl = dist.table.rate_limiter
            print(f"actor_steps={steps:6.0f} learner_steps="
                  f"{int(dist.learner.state.steps):5d} "
                  f"inserts={rl.inserts} samples={rl.samples}")
    finally:
        dist.stop()

    # evaluate the final policy
    policy = make_eval_policy(spec, cfg)
    actor = FeedForwardActor(policy, VariableClient(dist.learner))
    loop = EnvironmentLoop(Catch(seed=99), actor)
    rets = [loop.run_episode()["episode_return"] for _ in range(30)]
    print(f"eval return over 30 episodes: {np.mean(rets):+.2f}")


if __name__ == "__main__":
    main()
