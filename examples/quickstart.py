"""Quickstart: the Acme pattern in 30 lines — build a DQN agent, run the
environment loop, watch it learn Catch.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.agents.builders import make_agent
from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.core import EnvironmentLoop, make_environment_spec
from repro.envs import Catch


def main():
    environment = Catch(seed=1)
    spec = make_environment_spec(environment)

    config = DQNConfig(min_replay_size=50, samples_per_insert=0.0,
                       batch_size=32, n_step=1, epsilon=0.2)
    agent = make_agent(DQNBuilder(spec, config, seed=0))

    loop = EnvironmentLoop(environment, agent)
    returns = []
    for episode in range(250):
        result = loop.run_episode()
        returns.append(result["episode_return"])
        if (episode + 1) % 50 == 0:
            print(f"episode {episode + 1:4d}  "
                  f"avg_return(last50) {np.mean(returns[-50:]):+.2f}")
    assert np.mean(returns[-50:]) > 0, "agent should have learned catch"
    print("quickstart OK")


if __name__ == "__main__":
    main()
