"""Quickstart: the Acme pattern in a dozen lines — declare an experiment
(builder factory + environment factory), run it, watch DQN learn Catch.

The same ``ExperimentConfig`` drives every execution mode: swap
``run_experiment`` for ``run_distributed_experiment(config, num_actors=4)``
and the identical builder runs as a Launchpad-lite program instead
(see examples/distributed_dqn_catch.py).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.agents.dqn import DQNBuilder, DQNConfig
from repro.envs import Catch
from repro.experiments import ExperimentConfig, run_experiment


def main():
    config = ExperimentConfig(
        builder_factory=lambda spec: DQNBuilder(
            spec, DQNConfig(min_replay_size=50, samples_per_insert=0.0,
                            batch_size=32, n_step=1, epsilon=0.2), seed=0),
        environment_factory=lambda seed: Catch(seed=seed),
        seed=1,
        num_episodes=250,
        eval_every=50,
        eval_episodes=20,
    )
    result = run_experiment(config)

    for steps, ret in result.eval_returns:
        print(f"actor_steps {steps:5d}  eval_return {ret:+.2f}")
    assert np.mean(result.train_returns[-50:]) > 0, \
        "agent should have learned catch"
    print("quickstart OK")


if __name__ == "__main__":
    main()
