"""Offline RL (§2.6/§3.7): train BC and offline DQN from a fixed dataset —
no actors, just a learner + dataset, then an evaluator.

  PYTHONPATH=src python examples/offline_bc.py
"""
import jax
import numpy as np

from repro.adders import NStepTransitionAdder
from repro.agents import bc as bc_lib
from repro.agents import dqn as dqn_lib
from repro.core import EnvironmentLoop, FeedForwardActor, VariableClient, make_environment_spec
from repro.envs import Catch
from repro.replay import MinSize, Table, Uniform, dataset_from_list


def collect(episodes=120, seed=0):
    env = Catch(seed=seed)
    table = Table("data", 1 << 20, Uniform(0), MinSize(1))
    adder = NStepTransitionAdder(table, 1, 0.99)
    for _ in range(episodes):
        ts = env.reset()
        adder.add_first(ts)
        while not ts.last():
            board = ts.observation
            ball = int(np.argmax(board[:-1].max(axis=0)))
            paddle = int(np.argmax(board[-1]))
            a = int(1 + np.sign(ball - paddle))
            ts = env.step(a)
            adder.add(a, ts)
    return [table._items[k].data for k in table._order]


def evaluate(learner, policy, episodes=25):
    actor = FeedForwardActor(policy, VariableClient(learner))
    loop = EnvironmentLoop(Catch(seed=123), actor)
    return np.mean([loop.run_episode()["episode_return"]
                    for _ in range(episodes)])


def main():
    spec = make_environment_spec(Catch(seed=0))
    items = collect()
    print(f"dataset: {len(items)} transitions from an expert policy")

    cfg = bc_lib.BCConfig()
    learner = bc_lib.make_learner(spec, cfg, dataset_from_list(items, 64),
                                  jax.random.key(0))
    for i in range(400):
        m = learner.step()
    print(f"BC final loss {m['loss']:.4f}  "
          f"eval return {evaluate(learner, bc_lib.make_eval_policy(spec, cfg)):+.2f}")

    qcfg = dqn_lib.DQNConfig(prioritized=False)
    qlearner = dqn_lib.make_learner(spec, qcfg, dataset_from_list(items, 64),
                                    jax.random.key(1))
    for i in range(400):
        m = qlearner.step()
    print(f"offline DQN final loss {m['loss']:.4f}  "
          f"eval return {evaluate(qlearner, dqn_lib.make_eval_policy(spec, qcfg)):+.2f}")


if __name__ == "__main__":
    main()
