"""Offline RL (§2.6/§3.7): train BC and offline DQN from a fixed dataset —
no actors, just a learner + dataset, then an evaluator.

BC goes through the experiments API (``BCBuilder`` is an offline
``AgentBuilder``: no adder, dataset pre-loaded into the replay table);
the offline-DQN section applies the DQN *learner* directly to the same
dataset — the paper's point that learners are reusable outside the
actor/replay loop.

  PYTHONPATH=src python examples/offline_bc.py
"""
import jax
import numpy as np

from repro.adders import NStepTransitionAdder
from repro.agents import bc as bc_lib
from repro.agents import dqn as dqn_lib
from repro.core import EnvironmentLoop, FeedForwardActor, VariableClient, make_environment_spec
from repro.envs import Catch
from repro.experiments import ExperimentConfig, run_offline_experiment
from repro.replay import MinSize, Table, Uniform, dataset_from_list


def collect(episodes=120, seed=0):
    env = Catch(seed=seed)
    table = Table("data", 1 << 20, Uniform(0), MinSize(1))
    adder = NStepTransitionAdder(table, 1, 0.99)
    for _ in range(episodes):
        ts = env.reset()
        adder.add_first(ts)
        while not ts.last():
            board = ts.observation
            ball = int(np.argmax(board[:-1].max(axis=0)))
            paddle = int(np.argmax(board[-1]))
            a = int(1 + np.sign(ball - paddle))
            ts = env.step(a)
            adder.add(a, ts)
    return [table._items[k].data for k in table._order]


def evaluate(learner, policy, episodes=25):
    actor = FeedForwardActor(policy, VariableClient(learner))
    loop = EnvironmentLoop(Catch(seed=123), actor)
    return np.mean([loop.run_episode()["episode_return"]
                    for _ in range(episodes)])


def main():
    items = collect()
    print(f"dataset: {len(items)} transitions from an expert policy")

    # BC through the offline experiments path
    config = ExperimentConfig(
        builder_factory=lambda spec: bc_lib.BCBuilder(
            spec, items, bc_lib.BCConfig(), seed=0),
        environment_factory=lambda seed: Catch(seed=seed),
        seed=0,
        eval_episodes=25,
    )
    result = run_offline_experiment(config, num_learner_steps=400)
    print(f"BC learner steps {result.learner_steps}  "
          f"eval return {result.final_eval_return:+.2f}")

    # offline double-DQN: the same learner module, fed the fixed dataset
    spec = make_environment_spec(Catch(seed=0))
    qcfg = dqn_lib.DQNConfig(prioritized=False)
    qlearner = dqn_lib.make_learner(spec, qcfg, dataset_from_list(items, 64),
                                    jax.random.key(1))
    for i in range(400):
        m = qlearner.step()
    print(f"offline DQN final loss {m['loss']:.4f}  "
          f"eval return {evaluate(qlearner, dqn_lib.make_eval_policy(spec, qcfg)):+.2f}")


if __name__ == "__main__":
    main()
