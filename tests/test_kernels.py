"""Per-kernel shape/dtype sweeps, allclose against the ref.py oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

RNG = np.random.RandomState(42)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-4


@pytest.mark.parametrize("b,h,sq,sk,d", [
    (1, 1, 128, 128, 64),
    (2, 2, 256, 256, 64),
    (1, 4, 256, 512, 128),
    (2, 1, 512, 512, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64), (False, None)])
def test_flash_attention_sweep(b, h, sq, sk, d, dtype, causal, window):
    if not causal and sq != sk:
        pytest.skip("non-causal cross shapes covered elsewhere")
    q = jnp.asarray(RNG.randn(b, h, sq, d), dtype)
    k = jnp.asarray(RNG.randn(b, h, sk, d), dtype)
    v = jnp.asarray(RNG.randn(b, h, sk, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("b,h,s,d", [
    (1, 1, 512, 64),
    (2, 4, 1024, 64),
    (1, 8, 512, 128),
    (4, 2, 2048, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, h, s, d, dtype):
    q = jnp.asarray(RNG.randn(b, h, d), dtype)
    k = jnp.asarray(RNG.randn(b, s, h, d), dtype)
    v = jnp.asarray(RNG.randn(b, s, h, d), dtype)
    lengths = jnp.asarray(RNG.randint(1, s + 1, b), jnp.int32)
    out = ops.decode_attention(q, k, v, lengths, interpret=True)
    expected = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("b,h,s,d", [
    (8, 2, 8, 16),     # pow-2 padded batch over a window-8 ring cache
    (8, 4, 16, 32),    # the serve default: window 16, 4 heads post-GQA
    (4, 2, 8, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_policy_serve_shapes(b, h, s, d, dtype):
    """Kernel-vs-ref at the exact shapes the transformer-policy serve step
    emits: tiny window-length ring caches (s == sliding_window, far below
    the LLM-serving sweep above), the batch padded to a power-of-two bucket
    with scratch-slot rows, and cache-offset ``lengths`` mixing mid-episode
    rows (length == s after the ring wraps), fresh prefixes, and length-1
    pad/restart rows."""
    q = jnp.asarray(RNG.randn(b, h, d), dtype)
    k = jnp.asarray(RNG.randn(b, s, h, d), dtype)
    v = jnp.asarray(RNG.randn(b, s, h, d), dtype)
    lengths = np.full((b,), s, np.int32)
    lengths[1::3] = RNG.randint(2, s, len(lengths[1::3]))  # mid-prefix rows
    lengths[2::3] = 1                         # pad / episode-restart rows
    lengths = jnp.asarray(lengths)
    out = ops.decode_attention(q, k, v, lengths, block_k=min(512, s),
                               interpret=True)
    expected = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 256, 2, 32, 16, 64),
    (2, 512, 4, 64, 32, 128),
    (1, 512, 2, 64, 64, 256),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    x = jnp.asarray(RNG.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(b, s, h)) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.randn(h)) + 0.5, jnp.float32)
    B = jnp.asarray(RNG.randn(b, s, n), jnp.float32)
    C = jnp.asarray(RNG.randn(b, s, n), jnp.float32)
    y = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    y_ref, _ = ref.ssd_scan_ref(x, dt, A, B, C, chunk)
    scale = float(jnp.max(jnp.abs(y_ref))) + 1.0
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(y_ref) / scale, atol=1e-5)


def test_ssd_matches_naive_recurrence():
    """SSD chunked == direct per-token SSM recurrence (duality check)."""
    b, s, h, p, n = 1, 64, 2, 16, 8
    x = jnp.asarray(RNG.randn(b, s, h, p), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(b, s, h)) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.randn(h)) + 0.5, jnp.float32)
    B = jnp.asarray(RNG.randn(b, s, n), jnp.float32)
    C = jnp.asarray(RNG.randn(b, s, n), jnp.float32)
    y_ref, final = ref.ssd_scan_ref(x, dt, A, B, C, 16)

    state = np.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        a = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])      # (b,h)
        Bx = np.einsum("bn,bhp,bh->bhnp", np.asarray(B[:, t]),
                       np.asarray(x[:, t]), np.asarray(dt[:, t]))
        state = state * a[..., None, None] + Bx
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(C[:, t]), state))
    y_naive = np.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_ref), y_naive, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), state, atol=1e-4)


@pytest.mark.parametrize("T,B", [(16, 128), (64, 256), (100, 128)])
def test_vtrace_sweep(T, B):
    vals = jnp.asarray(RNG.randn(T, B), jnp.float32)
    nvals = jnp.asarray(RNG.randn(T, B), jnp.float32)
    rew = jnp.asarray(RNG.randn(T, B), jnp.float32)
    disc = jnp.asarray(RNG.rand(T, B) * 0.99, jnp.float32)
    rhos = jnp.asarray(np.abs(RNG.randn(T, B)) + 0.1, jnp.float32)
    vs, adv = ops.vtrace(vals, nvals, rew, disc, rhos, interpret=True)
    vs_ref, adv_ref = ref.vtrace_ref(vals, nvals, rew, disc, rhos)
    np.testing.assert_allclose(np.asarray(vs), np.asarray(vs_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(adv), np.asarray(adv_ref), atol=1e-4)


def test_vtrace_ref_matches_python_loop():
    T, B = 12, 3
    vals = RNG.randn(T, B).astype(np.float32)
    nvals = RNG.randn(T, B).astype(np.float32)
    rew = RNG.randn(T, B).astype(np.float32)
    disc = (RNG.rand(T, B) * 0.9).astype(np.float32)
    rhos = (np.abs(RNG.randn(T, B)) + 0.1).astype(np.float32)
    vs_ref, _ = ref.vtrace_ref(*map(jnp.asarray, (vals, nvals, rew, disc, rhos)))
    rho_c = np.minimum(rhos, 1.0)
    cs = np.minimum(rhos, 1.0)
    deltas = rho_c * (rew + disc * nvals - vals)
    acc = np.zeros(B, np.float32)
    out = np.zeros((T, B), np.float32)
    for t in reversed(range(T)):
        acc = deltas[t] + disc[t] * cs[t] * acc
        out[t] = vals[t] + acc
    np.testing.assert_allclose(np.asarray(vs_ref), out, atol=1e-5)
