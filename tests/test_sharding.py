"""Sharding-rule resolution: divisibility fallback, axis dedup, ZeRO specs,
param logical axes.  Uses a small host mesh (no 512-device env needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import BASE_RULES, ShardingRules
from repro.launch.param_sharding import param_logical_axes, tree_pspecs


class _FakeMesh:
    """Duck-typed mesh: ShardingRules only reads axis_names + devices.shape
    for spec resolution, so tests don't need 256 real devices."""

    def __init__(self, shape, names):
        import numpy as np
        self.axis_names = names
        self.devices = np.zeros(shape)


@pytest.fixture(scope="module")
def rules():
    return ShardingRules(_FakeMesh((4, 2), ("data", "model")))


def test_divisibility_fallback(rules):
    n = rules._axis_sizes["model"]
    spec = rules.mesh_axes(("vocab",), (n * 10 + 1,))
    assert spec == P(None)
    spec = rules.mesh_axes(("vocab",), (n * 10,))
    assert spec == P("model")


def test_axis_dedup_within_one_tensor(rules):
    # both dims want 'model': only the first (divisible) one gets it
    n = rules._axis_sizes["model"]
    spec = rules.mesh_axes(("vocab", "embed_d"), (n * 4, n * 4))
    assert spec == P("model", None)
    # vocab not divisible -> embed_d picks up the axis
    spec = rules.mesh_axes(("vocab", "embed_d"), (n * 4 + 1, n * 4))
    assert spec == P(None, "model")


def test_zero_spec_adds_data_axis(rules):
    d = rules._axis_sizes["data"]
    base = rules.mesh_axes(("layers", "d_model", "ff"), (4 * d, 8, 16))
    z = rules.zero_spec(base, (4 * d, 8, 16))
    assert "data" in jax.tree.leaves(tuple(z)) or z[0] == "data"


def test_param_logical_axes_by_name():
    path = (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("attn"),
            jax.tree_util.DictKey("wq"))
    axes = param_logical_axes(path, (4, 128, 8, 32))   # stacked layers
    assert axes == ("layers", "d_model", "heads", "head_dim")
    path = (jax.tree_util.DictKey("embed"), jax.tree_util.DictKey("table"))
    assert param_logical_axes(path, (1024, 128)) == ("vocab", "embed_d")
    path = (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("moe"),
            jax.tree_util.DictKey("experts"), jax.tree_util.DictKey("w_gate"))
    assert param_logical_axes(path, (4, 8, 128, 64)) == (
        "layers", "experts", "d_model", "expert_ff")


def test_tree_pspecs_covers_full_model(rules):
    from repro.configs import ARCHS, reduced
    from repro.models import transformer
    cfg = reduced(ARCHS["qwen2-moe-a2.7b"])
    params = jax.eval_shape(
        lambda: transformer.init(jax.random.key(0), cfg, jnp.float32))
    specs = tree_pspecs(params, rules)
    # every leaf must have a spec of the right rank
    def check(path, spec, leaf):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
    jax.tree_util.tree_map_with_path(
        lambda p, s, l: check(p, s, l), specs, params)


def test_activation_shard_noop_outside_context():
    from repro.sharding import shard
    x = jnp.ones((4, 8))
    y = shard(x, "batch", "d_model")
    assert (y == x).all()
