import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.adders import EpisodeAdder, NStepTransitionAdder, SequenceAdder
from repro.core import types
from repro.replay import MinSize, Table, Uniform


def _drain(table):
    return [table._items[k].data for k in table._order]


def _run_episode(adder, rewards, discounts=None, obs0=0):
    discounts = discounts or [1.0] * len(rewards)
    adder.add_first(types.restart(np.float32(obs0)))
    for i, (r, d) in enumerate(zip(rewards, discounts)):
        last = i == len(rewards) - 1
        ts = (types.termination(r, np.float32(obs0 + i + 1)) if last
              else types.transition(r, np.float32(obs0 + i + 1), d))
        adder.add(np.int32(i % 3), ts)


def test_nstep_adder_writes_all_transitions():
    t = Table("t", 1000, Uniform(0), MinSize(1))
    adder = NStepTransitionAdder(t, n_step=3, discount=0.9)
    _run_episode(adder, [1.0, 2.0, 3.0, 4.0, 5.0])
    items = _drain(t)
    assert len(items) == 5           # one per source step (flushed at end)
    first = items[0]
    # r = r0 + g*r1 + g^2*r2
    assert first.reward == pytest.approx(1 + 0.9 * 2 + 0.81 * 3)
    assert first.discount == pytest.approx(0.9 ** 3)
    assert float(first.observation) == 0.0
    assert float(first.next_observation) == 3.0
    # tail transitions shrink towards the terminal
    last = items[-1]
    assert last.reward == pytest.approx(5.0)
    assert last.discount == pytest.approx(0.0)  # terminal discount folds in


@settings(max_examples=30, deadline=None)
@given(
    rewards=st.lists(st.floats(-5, 5), min_size=1, max_size=12),
    n=st.integers(1, 5),
    gamma=st.floats(0.5, 1.0),
)
def test_nstep_adder_matches_oracle(rewards, n, gamma):
    """Property: every written item equals the direct n-step aggregate."""
    t = Table("t", 10_000, Uniform(0), MinSize(1))
    adder = NStepTransitionAdder(t, n_step=n, discount=gamma)
    _run_episode(adder, rewards)
    items = _drain(t)
    T = len(rewards)
    assert len(items) == T
    discounts = [1.0] * (T - 1) + [0.0]
    for s, item in enumerate(items):
        horizon = min(n, T - s)
        r, g = 0.0, 1.0
        for i in range(horizon):
            r += g * rewards[s + i]
            g *= gamma * discounts[s + i]
        assert float(item.reward) == pytest.approx(r, rel=1e-5, abs=1e-5)
        assert float(item.discount) == pytest.approx(g, rel=1e-5, abs=1e-6)
        assert float(item.observation) == s
        assert float(item.next_observation) == min(s + horizon, T)


def test_sequence_adder_overlap_and_padding():
    t = Table("t", 1000, Uniform(0), MinSize(1))
    adder = SequenceAdder(t, sequence_length=4, period=2)
    _run_episode(adder, [1.0] * 7)
    items = _drain(t)
    # writes at t=4 (steps 0-3), t=6 (steps 2-5), then flush (steps 4-6 padded)
    assert len(items) == 3
    assert items[0]["mask"].sum() == 4
    assert items[1]["observation"][0] == 2.0
    assert items[2]["mask"].sum() == 3          # padded final sequence
    assert items[2]["mask"].shape[0] == 4


def test_sequence_adder_extras_are_stored():
    t = Table("t", 1000, Uniform(0), MinSize(1))
    adder = SequenceAdder(t, sequence_length=2, period=2)
    adder.add_first(types.restart(np.float32(0)))
    adder.add(0, types.transition(1.0, np.float32(1)),
              extras={"logits": np.array([0.5, 0.5], np.float32)})
    adder.add(1, types.termination(1.0, np.float32(2)),
              extras={"logits": np.array([0.2, 0.8], np.float32)})
    items = _drain(t)
    assert items[0]["logits"].shape == (2, 2)


def test_episode_adder_whole_episode():
    t = Table("t", 1000, Uniform(0), MinSize(1))
    adder = EpisodeAdder(t)
    _run_episode(adder, [1.0, 0.0, 2.0])
    items = _drain(t)
    assert len(items) == 1
    assert items[0]["reward"].tolist() == [1.0, 0.0, 2.0]
