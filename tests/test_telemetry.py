"""Telemetry layer tests: registry units, merge semantics, hub
aggregation, disabled-mode no-ops, courier round-trip, and the
multiprocess acceptance run.

Structure mirrors the layer itself: pure-python registry/merge tests
first (no repro machinery), then the hub + pusher, then the courier
RPC boundary, then full runs through ``run_experiment`` /
``run_distributed_experiment``.
"""
import json
import pickle
import time

import numpy as np
import pytest

from repro.telemetry import (HUB_INTERFACE, NULL_METRIC, Counter, Gauge,
                             Histogram, MetricRegistry, MetricsHub,
                             MetricsPusher, WorkerTelemetry, format_report,
                             merge_snapshots, quantile, strip_reservoirs,
                             timer)
from repro.telemetry import registry as _registry


@pytest.fixture
def telemetry_state():
    """Restore the process-global registry to its import-time state so
    tests that configure() it can't leak into the rest of the suite."""
    yield
    _registry.unconfigure()


# ------------------------------------------------------------ registry units
def test_quantile_matches_numpy():
    rng = np.random.default_rng(0)
    values = sorted(rng.normal(size=257).tolist())
    for q in (0.0, 0.25, 0.5, 0.95, 0.99, 1.0):
        assert quantile(values, q) == pytest.approx(
            np.percentile(values, q * 100), rel=1e-9)


def test_quantile_edge_cases():
    assert np.isnan(quantile([], 0.5))
    assert quantile([3.0], 0.99) == 3.0


def test_histogram_exact_when_under_reservoir():
    h = Histogram("h", max_samples=512)
    for v in range(1, 101):
        h.observe(float(v))
    snap = h.snapshot()
    assert snap["count"] == 100
    assert snap["sum"] == pytest.approx(5050.0)
    assert snap["min"] == 1.0 and snap["max"] == 100.0
    assert snap["mean"] == pytest.approx(50.5)
    assert snap["p50"] == pytest.approx(np.percentile(range(1, 101), 50))
    assert snap["p95"] == pytest.approx(np.percentile(range(1, 101), 95))
    assert snap["p99"] == pytest.approx(np.percentile(range(1, 101), 99))


def test_histogram_reservoir_bounds_memory_keeps_exact_stats():
    h = Histogram("h", max_samples=64)
    for v in range(10_000):
        h.observe(float(v))
    snap = h.snapshot()
    # count/sum/min/max are exact regardless of sampling
    assert snap["count"] == 10_000
    assert snap["min"] == 0.0 and snap["max"] == 9999.0
    assert len(snap["reservoir"]) == 64
    # the uniform sample keeps quantiles honest (loose statistical bound)
    assert 3000 < snap["p50"] < 7000


def test_empty_histogram_snapshot():
    assert Histogram("h").snapshot() == {"type": "histogram", "count": 0}


def test_counter_and_gauge():
    c = Counter("c")
    c.inc()
    c.inc(5)
    assert c.snapshot() == {"type": "counter", "value": 6}
    g = Gauge("g")
    g.set(3)
    g.set(2.5)
    assert g.snapshot() == {"type": "gauge", "value": 2.5}


def test_registry_returns_same_metric_and_rejects_type_conflicts():
    reg = MetricRegistry(enabled=True)
    assert reg.counter("a/b") is reg.counter("a/b")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("a/b")


def test_registry_probes():
    reg = MetricRegistry(enabled=True)
    reg.probe("pool", lambda: {"held": 3, "free": 5})
    reg.probe("bad", lambda: 1 / 0)            # raising probe is skipped
    reg.probe("mixed", lambda: {"ok": 1.5, "label": "nope"})
    snap = reg.snapshot()
    assert snap["pool/held"] == {"type": "gauge", "value": 3.0}
    assert snap["pool/free"] == {"type": "gauge", "value": 5.0}
    assert snap["mixed/ok"]["value"] == 1.5
    assert not any(k.startswith("bad") for k in snap)
    assert "mixed/label" not in snap


def test_registry_probe_prefix_collision_dedupes():
    reg = MetricRegistry(enabled=True)
    reg.probe("engine", lambda: {"x": 1})
    reg.probe("engine", lambda: {"x": 2})
    snap = reg.snapshot()
    assert snap["engine/x"]["value"] == 1.0
    assert snap["engine#2/x"]["value"] == 2.0


def test_timer_observes_milliseconds():
    h = Histogram("h")
    with timer(h):
        time.sleep(0.01)
    snap = h.snapshot()
    assert snap["count"] == 1
    assert snap["min"] >= 5.0   # ms, not seconds


# ----------------------------------------------------------- disabled mode
def test_disabled_registry_is_noop():
    reg = MetricRegistry(enabled=False)
    c = reg.counter("c")
    h = reg.histogram("h")
    g = reg.gauge("g")
    # all three are the shared falsy null — hot paths skip clock reads
    assert c is NULL_METRIC and h is NULL_METRIC and g is NULL_METRIC
    assert not c and not h and not g
    c.inc()
    g.set(1.0)
    h.observe(2.0)
    with timer(h):
        pass
    reg.probe("pool", lambda: {"x": 1})
    assert reg.snapshot() == {}


def test_global_registry_disabled_until_configured(telemetry_state):
    _registry.unconfigure()
    assert not _registry.enabled()
    assert not _registry.is_configured()
    assert _registry.histogram("x") is NULL_METRIC
    _registry.configure(enabled=True, node="test")
    assert _registry.enabled()
    assert _registry.node_name() == "test"
    assert _registry.histogram("x")
    # configure() always starts fresh: no leakage between runs
    _registry.histogram("x").observe(1.0)
    _registry.configure(enabled=True, node="test2")
    assert _registry.snapshot() == {}


# ------------------------------------------------------------------- merge
def test_merge_counters_sum():
    merged = merge_snapshots({
        "a": {"events": {"type": "counter", "value": 3}},
        "b": {"events": {"type": "counter", "value": 4}},
    })
    assert merged["events"] == {"type": "counter", "value": 7, "nodes": 2}


def test_merge_gauges_mean_min_max():
    merged = merge_snapshots({
        "a": {"size": {"type": "gauge", "value": 10.0}},
        "b": {"size": {"type": "gauge", "value": 30.0}},
    })
    assert merged["size"]["mean"] == 20.0
    assert merged["size"]["min"] == 10.0
    assert merged["size"]["max"] == 30.0


def test_merge_histograms_recomputes_quantiles_from_reservoirs():
    h1, h2 = Histogram("h"), Histogram("h")
    for v in range(100):
        h1.observe(float(v))
    for v in range(100, 300):
        h2.observe(float(v))
    merged = merge_snapshots({"a": {"h": h1.snapshot()},
                              "b": {"h": h2.snapshot()}})["h"]
    combined = list(range(300))
    assert merged["count"] == 300
    assert merged["min"] == 0.0 and merged["max"] == 299.0
    # true cross-node quantiles, NOT the average of per-node percentiles
    assert merged["p50"] == pytest.approx(np.percentile(combined, 50))
    assert merged["p95"] == pytest.approx(np.percentile(combined, 95))
    avg_of_p50s = (h1.snapshot()["p50"] + h2.snapshot()["p50"]) / 2
    assert merged["p50"] != pytest.approx(avg_of_p50s)


def test_merge_skips_conflicting_types_and_handles_empty():
    merged = merge_snapshots({
        "a": {"m": {"type": "counter", "value": 1},
              "h": {"type": "histogram", "count": 0}},
        "b": {"m": {"type": "gauge", "value": 2.0},
              "h": {"type": "histogram", "count": 0}},
    })
    assert "m" not in merged
    assert merged["h"] == {"type": "histogram", "count": 0, "nodes": 2}


def test_strip_reservoirs():
    h = Histogram("h")
    h.observe(1.0)
    stripped = strip_reservoirs({"h": h.snapshot()})
    assert "reservoir" not in stripped["h"]
    assert stripped["h"]["count"] == 1


# --------------------------------------------------------------------- hub
def _snapshot_with(events: int) -> dict:
    reg = MetricRegistry(enabled=True)
    reg.counter("events").inc(events)
    reg.histogram("lat_ms").observe(float(events))
    return reg.snapshot()


def test_hub_aggregates_and_keeps_latest_per_node():
    hub = MetricsHub()
    hub.push("actor/0", _snapshot_with(5))
    hub.push("actor/1", _snapshot_with(7))
    hub.push("actor/0", _snapshot_with(10))   # supersedes the first push
    snap = hub.snapshot()
    assert sorted(snap["nodes"]) == ["actor/0", "actor/1"]
    assert snap["num_nodes"] == 2 and snap["num_pushes"] == 3
    assert snap["merged"]["events"]["value"] == 17
    assert snap["merged"]["lat_ms"]["count"] == 2
    assert hub.nodes() == ["actor/0", "actor/1"]
    assert hub.num_pushes() == 3
    report = hub.report()
    assert "2 node(s)" in report and "events" in report
    assert format_report(snap) == report


def test_hub_jsonl_export(tmp_path):
    path = tmp_path / "telemetry.jsonl"
    hub = MetricsHub(jsonl_path=str(path))
    hub.push("a", _snapshot_with(1))
    hub.push("b", _snapshot_with(2))
    hub.stop()
    records = [json.loads(line) for line in path.read_text().splitlines()]
    assert [r["node"] for r in records] == ["a", "b"]
    for r in records:
        assert r["metrics"]["events"]["type"] == "counter"
        assert "reservoir" not in r["metrics"]["lat_ms"]
    # stop() is idempotent and the hub stays readable afterwards
    hub.stop()
    assert hub.snapshot()["num_nodes"] == 2


def test_pusher_pushes_periodically_and_flushes_on_stop(telemetry_state):
    _registry.configure(enabled=True, node="w")
    _registry.counter("events").inc(3)
    hub = MetricsHub()
    pusher = MetricsPusher(hub, "w", period_s=0.02).start()
    deadline = time.time() + 5.0
    while hub.num_pushes() < 2 and time.time() < deadline:
        time.sleep(0.01)
    assert hub.num_pushes() >= 2
    _registry.counter("events").inc(1)
    pusher.stop()
    # the final flush after the loop exits captured the last increment
    assert hub.snapshot()["merged"]["events"]["value"] == 4
    pushes = hub.num_pushes()
    time.sleep(0.06)
    assert hub.num_pushes() == pushes   # really stopped


def test_worker_telemetry_install(telemetry_state):
    hub = MetricsHub()
    # already-configured process (local launcher): install is a no-op
    _registry.configure(enabled=True, node="services")
    assert WorkerTelemetry(hub, "actor/0").install() is None
    assert _registry.node_name() == "services"
    # fresh process (spawn child): install configures + starts a pusher
    _registry.unconfigure()
    pusher = WorkerTelemetry(hub, "actor/1", period_s=0.02).install()
    assert pusher is not None
    assert _registry.is_configured() and _registry.enabled()
    assert _registry.node_name() == "actor/1"
    _registry.counter("events").inc(2)
    pusher.stop()
    assert hub.snapshot()["merged"]["events"]["value"] == 2


# ------------------------------------------------------- courier round-trip
def test_hub_courier_roundtrip(telemetry_state):
    from repro.distributed.courier import serve

    hub = MetricsHub()
    server, handle = serve(hub, interface=HUB_INTERFACE, name="telemetry/hub")
    try:
        # client-side instrumentation: RPCs made while telemetry is on
        # show up as courier/client metrics in THIS process's registry
        _registry.configure(enabled=True, node="test")
        reg = MetricRegistry(enabled=True)
        h = reg.histogram("lat_ms")
        for v in range(100):
            h.observe(float(v))
        reg.counter("events").inc(7)
        handle.push("worker/0", reg.snapshot())
        handle.push("worker/1", reg.snapshot())

        snap = handle.snapshot()
        assert sorted(snap["nodes"]) == ["worker/0", "worker/1"]
        merged = snap["merged"]
        assert merged["events"]["value"] == 14
        # reservoirs crossed the wire intact: merged count doubles and
        # the stripped wire-format summary keeps its quantiles
        assert merged["lat_ms"]["count"] == 200
        assert merged["lat_ms"]["p50"] == pytest.approx(
            np.percentile(range(100), 50))
        assert "reservoir" not in merged["lat_ms"]
        assert handle.nodes() == ["worker/0", "worker/1"]
        assert handle.num_pushes() == 2

        # both RPC sides of the push were themselves measured
        local = _registry.snapshot()
        client_lat = local["courier/client/telemetry/hub/push/latency_ms"]
        server_lat = local["courier/server/telemetry/hub/push/latency_ms"]
        assert client_lat["count"] >= 2 and server_lat["count"] >= 2
        assert local["courier/client/telemetry/hub/push/bytes_sent"][
            "value"] > 0

        # WorkerTelemetry pickles with the remote handle inside — the
        # exact payload the multiprocess launcher ships to spawn children
        wt = pickle.loads(pickle.dumps(
            WorkerTelemetry(handle, "actor/0", period_s=0.02)))
        assert wt.node == "actor/0"
        wt.hub.push("actor/0", reg.snapshot())
        assert handle.num_pushes() == 3
    finally:
        server.stop()


# ----------------------------------------------------------- full-run paths
def test_run_experiment_telemetry_extras(telemetry_state, tmp_path):
    from conftest import make_dqn_catch_config
    from repro.experiments import run_experiment

    jsonl = tmp_path / "run.jsonl"
    config = make_dqn_catch_config(
        seed=0, num_episodes=4, eval_episodes=0, min_replay_size=20,
        samples_per_insert=2.0, batch_size=8,
        telemetry=True, telemetry_jsonl=str(jsonl))
    result = run_experiment(config)
    tel = result.extras["telemetry"]
    assert sorted(tel["nodes"]) == ["local"]
    merged = tel["merged"]
    # replay instrumentation: block-time histograms + occupancy probe
    assert merged["replay/insert_block_ms"]["count"] > 0
    assert merged["replay/size"]["mean"] > 0   # merged gauges: mean/min/max
    assert jsonl.exists() and jsonl.read_text().strip()


def test_run_experiment_telemetry_off_by_default(telemetry_state):
    from conftest import make_dqn_catch_config
    from repro.experiments import run_experiment

    config = make_dqn_catch_config(
        seed=0, num_episodes=2, eval_episodes=0, min_replay_size=20,
        samples_per_insert=2.0, batch_size=8)
    result = run_experiment(config)
    assert "telemetry" not in result.extras
    assert not _registry.enabled()


# ------------------------------------------- multiprocess acceptance (slow)
@pytest.mark.slow
def test_multiprocess_telemetry_acceptance(telemetry_state, tmp_path):
    """Acceptance: a multiprocess DQN-on-Catch run with ``telemetry=True``
    produces a merged snapshot with courier RPC latency histograms, replay
    per-shard occupancy, and inference batch-occupancy stats from >= 3
    distinct worker nodes."""
    from conftest import make_dqn_catch_config
    from repro.experiments import run_distributed_experiment

    jsonl = tmp_path / "telemetry.jsonl"
    config = make_dqn_catch_config(
        seed=0, eval_episodes=0, num_replay_shards=2,
        min_replay_size=30, samples_per_insert=2.0, batch_size=8,
        launcher="multiprocess", inference="server",
        telemetry=True, telemetry_push_period_s=0.2,
        telemetry_jsonl=str(jsonl))
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=600,
                                        timeout_s=240)
    tel = result.extras["telemetry"]
    nodes = set(tel["nodes"])
    assert {"actor/0", "actor/1", "services"} <= nodes
    assert tel["num_pushes"] >= len(nodes)
    merged = tel["merged"]

    # courier RPC tracing: client side (from the actor children) and
    # server side (parent-resident services) both measured the hot edges
    client_lat = [n for n in merged
                  if n.startswith("courier/client/") and
                  n.endswith("/latency_ms")]
    server_lat = [n for n in merged
                  if n.startswith("courier/server/") and
                  n.endswith("/latency_ms")]
    assert client_lat and server_lat
    sel = merged["courier/client/inference/select_action/latency_ms"]
    assert sel["count"] > 0 and sel["p95"] >= sel["p50"] > 0
    assert merged[
        "courier/client/inference/select_action/bytes_sent"]["value"] > 0

    # replay per-shard occupancy + block-time histograms
    for shard in ("replay/shard_0", "replay/shard_1"):
        assert merged[f"{shard}/size"]["mean"] > 0
        assert merged[f"{shard}/insert_block_ms"]["count"] > 0

    # inference batching: queue waits and batch occupancy on the server
    assert merged["inference/batch_occupancy"]["count"] > 0
    assert 0.0 < merged["inference/batch_occupancy"]["mean"] <= 1.0
    assert merged["inference/queue_wait_ms"]["count"] > 0
    assert merged["inference/server/requests"]["mean"] > 0

    # per-node attribution: actor children report their client latencies
    for actor in ("actor/0", "actor/1"):
        node_metrics = tel["nodes"][actor]
        assert any(n.startswith("courier/client/") for n in node_metrics)

    # JSONL export captured pushes from multiple nodes
    records = [json.loads(line) for line in
               jsonl.read_text().splitlines()]
    assert {"actor/0", "actor/1", "services"} <= {r["node"]
                                                  for r in records}
