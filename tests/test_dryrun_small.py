"""Dry-run smoke: one fast (arch x shape) combo lowers + compiles on the
256-chip production mesh.  Runs in a SUBPROCESS because the 512-device
XLA_FLAGS must be set before jax initializes (the rest of the suite sees 1
device)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [("whisper-base", "decode_32k")])
def test_dryrun_combo_compiles(tmp_path, arch, shape):
    out = tmp_path / "dry.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(out)],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["status"] == "ok", rec.get("error")
    assert rec["chips"] == 256
    assert rec["memory"]["fits_hbm"]
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["hlo"]["flops"] > 0
