"""Fully asynchronous learner training: the async-vs-sync parity net.

The credibility net for ``learner_sync="async"`` and shard-affine routing:

- ``weighted_average_states`` against hand-computed pytree expectations
  (float weighting, integer-counter exactness, single-state identity);
- ``AsyncParameterService`` merge math per mode (mean / ema /
  step_weighted), the single-contribution verbatim guarantee, staleness
  bounds, lazy blend recomputation, stop/mark_down/state_dict semantics;
- 1-replica async vs the plain learner — allclose (in fact equal) params
  from the same seed on identical batches, both at the learner level and
  through ``run_experiment`` (the heart of the parity net: async training
  with one replica IS the plain learner, bit for bit);
- shard-affine adder routing: ``ShardWriter`` global-key encoding with
  exact key accounting, priority updates routing back to the owning shard,
  routed-vs-round-robin sampling agreement, and one ``ExperimentConfig``
  driving affinity + async end to end with routing/staleness telemetry;
- program-graph placement: ``learner/param_service`` replaces
  ``learner/param_server``, replica workers run in push/pull mode;
- 2-replica async DQN-on-Catch learns (mean eval return clears the
  random-policy floor) under both launchers — the acceptance criterion,
  driven through the UNCHANGED ``DQNBuilder``.

Factories come from ``conftest`` so the multiprocess backend can pickle
them into spawn children.
"""
import dataclasses
import pickle

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import make_dqn_catch_config
from repro.core import make_environment_spec
from repro.envs import Catch
from repro.learners import (ASYNC_PARAM_SERVICE_INTERFACE,
                            AsyncParameterService, MultiLearner,
                            ParameterServer, weighted_average_states)
from repro.replay import ShardedReplay, ShardWriter, make_replay_shards
from repro.replay.dataset import ReplaySample, SampleInfo

CATCH_FLOOR = -0.6   # random policy mean return on Catch is ~-1..-0.6


# ----------------------------------------------------------------- helpers
def _catch_spec():
    return make_environment_spec(Catch(seed=0))


def _dqn_builder(seed=0, **overrides):
    from repro.agents.dqn import DQNBuilder, DQNConfig
    kwargs = dict(min_replay_size=8, samples_per_insert=0.0, batch_size=8,
                  n_step=1, prioritized=False)
    kwargs.update(overrides)
    return DQNBuilder(_catch_spec(), DQNConfig(**kwargs), seed=seed)


def _synthetic_batches(num_batches, batch_size=8, seed=0):
    """Deterministic DQN-shaped ReplaySample batches (Catch observations)."""
    from repro.core.types import Transition
    rng = np.random.RandomState(seed)
    batches = []
    for b in range(num_batches):
        obs = rng.rand(batch_size, 10, 5).astype(np.float32)
        next_obs = rng.rand(batch_size, 10, 5).astype(np.float32)
        data = Transition(
            observation=obs,
            action=rng.randint(0, 3, size=batch_size).astype(np.int32),
            reward=rng.randn(batch_size).astype(np.float32),
            discount=np.ones(batch_size, np.float32),
            next_observation=next_obs)
        info = SampleInfo(np.arange(batch_size, dtype=np.int64),
                          np.full(batch_size, 1.0 / 64))
        batches.append(ReplaySample(info, data))
    return batches


def _tree_allclose(a, b, **kw):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(flat_a) == len(flat_b)
    for x, y in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


def _w(value):
    """The one-leaf pytree the service-math tests blend."""
    return {"w": jnp.asarray(value, jnp.float32)}


class _StubLearner:
    """Deterministic 'learner': step() adds 1.0 to its single param."""

    def __init__(self, w):
        self.state = _w(w)

    def step(self):
        self.state = {"w": self.state["w"] + 1.0}
        return {}


def _make_uniform_table():
    from repro.replay import MinSize, Table, Uniform
    return Table("t", 64, Uniform(0), MinSize(1))


class _DummyShard:
    """Picklable minimal shard: monotonically numbered local keys."""

    def __init__(self):
        self.count = 0

    def insert(self, data, priority=1.0, timeout=None):
        key = self.count
        self.count += 1
        return key


# -------------------------------------------- weighted averaging math unit
def test_weighted_average_states_matches_hand_computed_mean():
    """Float leaves take the normalized weighted mean; dtypes survive."""
    s1 = {"params": {"w": jnp.array([1.0, 3.0]), "b": jnp.array(2.0)}}
    s2 = {"params": {"w": jnp.array([3.0, 5.0]), "b": jnp.array(6.0)}}
    merged = weighted_average_states([s1, s2], [1.0, 3.0])
    # weights normalize to 0.25/0.75
    np.testing.assert_allclose(merged["params"]["w"], [2.5, 4.5], rtol=1e-6)
    np.testing.assert_allclose(merged["params"]["b"], 5.0, rtol=1e-6)
    assert merged["params"]["w"].dtype == jnp.float32


def test_weighted_average_states_single_state_is_identity():
    """One state: the exact pytree comes back regardless of its weight —
    what makes a 1-replica async blend bit-equivalent to the plain
    learner."""
    state = {"w": jnp.array([1.0, 2.0]), "steps": jnp.array(7, jnp.int32)}
    assert weighted_average_states([state], [0.125]) is state


def test_weighted_average_states_integer_agreement_exact():
    """Agreeing integer counters merge exactly at any magnitude (no float
    round-trip), whatever the weights."""
    big = 2 ** 24 + 1
    s1 = {"steps": jnp.array(big, jnp.int32)}
    s2 = {"steps": jnp.array(big, jnp.int32)}
    merged = weighted_average_states([s1, s2], [1.0, 7.0])
    assert int(merged["steps"]) == big
    assert merged["steps"].dtype == jnp.int32


def test_weighted_average_states_integer_disagreement_floor_mean():
    """Disagreeing counters take the weighted floor mean in float64:
    steps 10 and 20 under weights 1:3 -> 0.25*10 + 0.75*20 = 17.5 -> 17."""
    s1 = {"steps": jnp.array(10, jnp.int32)}
    s2 = {"steps": jnp.array(20, jnp.int32)}
    merged = weighted_average_states([s1, s2], [1.0, 3.0])
    assert int(merged["steps"]) == 17
    assert merged["steps"].dtype == jnp.int32


def test_weighted_average_states_rejects_bad_args():
    state = _w(1.0)
    with pytest.raises(ValueError):
        weighted_average_states([], [])
    with pytest.raises(ValueError):
        weighted_average_states([state, state], [1.0])
    with pytest.raises(ValueError):
        weighted_average_states([state, state], [1.0, -0.5])
    with pytest.raises(ValueError):
        weighted_average_states([state, state], [0.0, 0.0])


# --------------------------------------------------- async service: merges
def test_async_service_single_contribution_is_verbatim():
    """One contributor: pull() returns the pushed pytree object itself —
    no averaging round-trip (the 1-replica parity guarantee)."""
    service = AsyncParameterService(num_replicas=2, merge="ema")
    assert service.pull() is None       # nothing pushed yet
    state = _w(2.0)
    service.push(0, state, step=5)
    assert service.pull() is state


def test_async_service_mean_merge_hand_computed():
    service = AsyncParameterService(2, merge="mean")
    service.push(0, _w(2.0), step=10)
    service.push(1, _w(6.0), step=8)
    np.testing.assert_allclose(service.pull()["w"], 4.0, rtol=1e-6)


def test_async_service_ema_merge_weights_by_staleness():
    """ema weight = alpha**age, age = max_step - step: steps 10 and 8 at
    alpha 0.5 weight 1 : 0.25 -> (1*2 + 0.25*6) / 1.25 = 2.8."""
    service = AsyncParameterService(2, merge="ema", ema_alpha=0.5)
    service.push(0, _w(2.0), step=10)
    service.push(1, _w(6.0), step=8)
    np.testing.assert_allclose(service.pull()["w"], 2.8, rtol=1e-6)


def test_async_service_step_weighted_merge():
    """step_weighted weight = 1 + step: steps 1 and 3 weight 2 : 4 ->
    (2*2 + 4*6) / 6 = 28/6."""
    service = AsyncParameterService(2, merge="step_weighted")
    service.push(0, _w(2.0), step=1)
    service.push(1, _w(6.0), step=3)
    np.testing.assert_allclose(service.pull()["w"], 28.0 / 6.0, rtol=1e-6)


def test_async_service_blend_is_lazy():
    """The blend recomputes only when a push changed something: repeated
    pulls share one merge; the next push dirties it again."""
    service = AsyncParameterService(2, merge="mean")
    service.push(0, _w(2.0), step=1)
    service.push(1, _w(4.0), step=1)
    service.pull()
    service.pull()
    assert service.rounds == 1
    service.push(0, _w(6.0), step=2)
    np.testing.assert_allclose(service.pull()["w"], 5.0, rtol=1e-6)
    assert service.rounds == 2


def test_async_service_staleness_bound_drops_old_contributions():
    """Contributions older than the bound leave the blend (and are
    counted); a fresh re-push re-enters."""
    service = AsyncParameterService(2, merge="mean", staleness_bound=2)
    service.push(0, _w(1.0), step=0)
    fresh = _w(5.0)
    service.push(1, fresh, step=10)
    # replica 0's state is 10 steps stale > bound 2: the blend is the
    # fresh contribution verbatim (single survivor)
    assert service.pull() is fresh
    stats = service.stats()
    assert stats["staleness_bound"] == 2
    assert stats["dropped_stale"] == 1
    assert stats["contributors"] == 2   # still tracked, just not blended
    # a fresh push from replica 0 rejoins the blend
    service.push(0, _w(3.0), step=9)
    np.testing.assert_allclose(service.pull()["w"], 4.0, rtol=1e-6)


def test_async_service_invalidate_drops_contribution():
    service = AsyncParameterService(2, merge="mean")
    service.push(0, _w(2.0), step=1)
    survivor = _w(8.0)
    service.push(1, survivor, step=1)
    service.invalidate(0)
    assert service.pull() is survivor
    assert service.stats()["contributors"] == 1


# ----------------------------------------------- async service: lifecycle
def test_async_service_stats_and_activity():
    service = AsyncParameterService(3, merge="ema")
    service.push(0, _w(1.0), step=4)
    service.push(1, _w(2.0), step=6)
    service.pull()
    assert service.stats() == {"num_replicas": 3, "merge": "ema",
                               "pushes": 2, "pulls": 1, "merges": 1,
                               "contributors": 2, "max_step": 6}
    assert service.activity() == 3      # pushes + pulls


def test_async_service_stop_quiesces_push_and_pull():
    service = AsyncParameterService(1)
    service.push(0, _w(1.0), step=1)
    service.stop()
    assert service.stopped
    assert service.pull() is None       # a stopping fleet adopts nothing
    service.push(0, _w(9.0), step=2)    # no-op, not an error
    assert service.stats()["pushes"] == 1


def test_async_service_mark_down_raises_service_unavailable():
    """Simulated death: the data path raises ServiceUnavailable (a
    ConnectionError, so replica workers degrade through their existing
    handler) while metadata stays readable for the watchdog."""
    from repro.distributed.courier import ServiceUnavailable

    assert issubclass(ServiceUnavailable, ConnectionError)
    service = AsyncParameterService(2)
    service.push(0, _w(1.0), step=1)
    service.mark_down()
    with pytest.raises(ServiceUnavailable):
        service.push(1, _w(2.0), step=1)
    with pytest.raises(ServiceUnavailable):
        service.pull()
    assert service.stats()["pushes"] == 1      # metadata path stays up
    assert "contrib" in service.state_dict()
    service.mark_up()
    assert service.pull() is not None


def test_async_service_state_dict_roundtrip():
    """A restored service blends exactly what the snapshot held."""
    service = AsyncParameterService(2, merge="mean")
    service.push(0, _w(2.0), step=3)
    service.push(1, _w(6.0), step=5)
    before = service.pull()
    fresh = AsyncParameterService(2, merge="mean")
    fresh.load_state_dict(service.state_dict())
    _tree_allclose(fresh.pull(), before)
    assert fresh.stats()["max_step"] == 5
    assert fresh.stats()["pushes"] == 2


def test_async_service_rejects_bad_args():
    with pytest.raises(ValueError):
        AsyncParameterService(num_replicas=0)
    with pytest.raises(ValueError):
        AsyncParameterService(2, merge="median")
    with pytest.raises(ValueError):
        AsyncParameterService(2, ema_alpha=0.0)
    with pytest.raises(ValueError):
        AsyncParameterService(2, ema_alpha=1.5)
    with pytest.raises(ValueError):
        AsyncParameterService(2, staleness_bound=0)
    service = AsyncParameterService(2)
    with pytest.raises(ValueError):
        service.push(2, _w(1.0), step=1)
    with pytest.raises(ValueError):
        service.push(0, _w(1.0), step=-1)


# ------------------------------------------------------------- parity net
def test_one_replica_async_multi_learner_matches_plain_learner():
    """The heart of the async parity net: on IDENTICAL sampled batches
    from the same seed, a 1-replica async MultiLearner and the plain
    learner produce allclose (equal) params — every pull returns the
    replica's own state verbatim, so adopting the blend is a no-op."""
    batches = _synthetic_batches(12)
    plain = _dqn_builder(seed=3).make_learner(iter(list(batches)))
    multi = MultiLearner(
        [_dqn_builder(seed=3).make_learner(iter(list(batches)))],
        average_period=4, async_service=AsyncParameterService(1))
    for _ in range(12):
        plain.step()
        multi.step()
    _tree_allclose(multi.state.params, plain.state.params)
    _tree_allclose(multi.state.target_params, plain.state.target_params)
    _tree_allclose(multi.state.opt_state, plain.state.opt_state)
    assert int(multi.state.steps) == int(plain.state.steps) == 12
    service = multi.async_service.stats()
    assert service["pushes"] == service["pulls"] == 3   # 12 steps / period 4
    assert service["contributors"] == 1


def test_run_experiment_async_parity_with_single_learner_path():
    """learner_sync='async' engages the multi-learner machinery even at
    one replica and lands on exactly the same params as the default path —
    same seed, same env stream, same sampled batches."""
    from repro.experiments import run_experiment

    base = make_dqn_catch_config(
        seed=0, min_replay_size=16, samples_per_insert=0.0, batch_size=16,
        prioritized=False, num_episodes=15, eval_episodes=0)
    plain = run_experiment(base)
    asynced = run_experiment(dataclasses.replace(
        base, learner_sync="async", learner_average_period=7))
    assert plain.learner_steps == asynced.learner_steps > 0
    _tree_allclose(asynced.learner.state.params, plain.learner.state.params)
    _tree_allclose(asynced.learner.state.opt_state,
                   plain.learner.state.opt_state)
    learners = asynced.extras["learners"]
    assert learners["num_replicas"] == 1
    assert learners["sync"] == "async"
    assert learners["service"]["contributors"] == 1
    assert learners["per_replica_steps"] == [asynced.learner_steps]
    assert "learners" not in plain.extras


def test_sequential_async_schedule_pushes_at_own_period():
    """2 stub replicas, period 1, mean merge — fully hand-computed: each
    replica pushes/pulls at ITS OWN boundary (no fleet-wide rendezvous),
    a lone contributor adopts its own state verbatim, and later pulls
    blend both contributions."""
    multi = MultiLearner([_StubLearner(0.0), _StubLearner(10.0)],
                         average_period=1,
                         async_service=AsyncParameterService(2, merge="mean"))
    multi.step()   # replica 0: w=1, push(0, 1, step=1), pull -> verbatim 1
    np.testing.assert_allclose(multi.replicas[0].state["w"], 1.0)
    multi.step()   # replica 1: w=11, push(1, 11, 1), pull -> mean(1,11)=6
    np.testing.assert_allclose(multi.replicas[1].state["w"], 6.0)
    multi.step()   # replica 0: w=2, push(0, 2, 2), pull -> mean(2,11)=6.5
    np.testing.assert_allclose(multi.replicas[0].state["w"], 6.5)
    stats = multi.stats()
    assert stats["sync"] == "async"
    assert stats["per_replica_steps"] == [2, 1]
    assert stats["service"]["contributors"] == 2
    assert stats["service"]["max_step"] == 2


def test_multi_learner_rejects_both_server_and_service():
    with pytest.raises(ValueError, match="not both"):
        MultiLearner([_StubLearner(0.0)],
                     param_server=ParameterServer(1, 1),
                     async_service=AsyncParameterService(1))


# ------------------------------------------------------ config validation
def test_experiment_config_validates_sync_and_routing():
    base = make_dqn_catch_config(seed=0)
    with pytest.raises(ValueError, match="learner_sync"):
        dataclasses.replace(base, learner_sync="eventually")
    with pytest.raises(ValueError, match="barrier_timeout_s"):
        dataclasses.replace(base, learner_sync="quorum")
    with pytest.raises(ValueError, match="incompatible"):
        dataclasses.replace(base, learner_sync="async",
                            barrier_timeout_s=1.0)
    with pytest.raises(ValueError, match="incompatible"):
        dataclasses.replace(base, learner_sync="async",
                            barrier_timeout_s=1.0, min_quorum=1)
    with pytest.raises(ValueError, match="replay_routing"):
        dataclasses.replace(base, replay_routing="sticky")


def test_builder_options_validate_sync_and_routing():
    from repro.builders.base import BuilderOptions

    with pytest.raises(ValueError, match="learner_sync"):
        BuilderOptions(learner_sync="eventually")
    with pytest.raises(ValueError, match="replay_routing"):
        BuilderOptions(replay_routing="sticky")


def test_make_agent_rejects_async_for_offline_builders():
    from repro.agents.bc import BCBuilder, BCConfig
    from repro.agents.builders import make_agent
    from repro.core.types import Transition

    items = [Transition(np.zeros((10, 5), np.float32), np.int32(i % 3),
                        np.float32(0.0), np.float32(1.0),
                        np.zeros((10, 5), np.float32)) for i in range(8)]
    builder = BCBuilder(_catch_spec(), items, BCConfig(batch_size=4), seed=0)
    with pytest.raises(ValueError, match="offline"):
        make_agent(builder, learner_sync="async")


def test_make_distributed_agent_rejects_async_with_quorum_knobs():
    from repro.agents.builders import make_distributed_agent
    from conftest import DQNCatchBuilderFactory, catch_env_factory

    builder = DQNCatchBuilderFactory()(_catch_spec())
    with pytest.raises(ValueError, match="incompatible"):
        make_distributed_agent(builder, catch_env_factory, num_actors=1,
                               seed=0, num_learner_replicas=2,
                               learner_sync="async", barrier_timeout_s=1.0)


# --------------------------------------------------- shard-affine routing
def test_shard_writer_global_key_encoding_exact():
    """Writer on shard 1 of 3: insert k lands at global key k*3 + 1, and
    priority updates for foreign shards are a loud routing bug."""
    table = _make_uniform_table()
    writer = ShardWriter(table, shard_idx=1, num_shards=3)
    keys = [writer.insert(np.full(3, k, np.float32)) for k in range(5)]
    assert keys == [1, 4, 7, 10, 13]
    assert writer.size() == 5
    writer.update_priorities([4, 10], [2.0, 3.0])       # owned keys: fine
    with pytest.raises(ValueError, match="shard 0"):
        writer.update_priorities([3], [1.0])            # 3 % 3 == shard 0
    with pytest.raises(ValueError):
        ShardWriter(table, shard_idx=3, num_shards=3)
    table.stop()


def test_shard_writer_keys_interchangeable_with_front_end():
    """shard_view inserts produce keys the ShardedReplay front-end routes
    back to the owning shard; only the written shard grows."""
    sharded = ShardedReplay.from_factory(_make_uniform_table, 2,
                                         routing="affinity")
    writer = sharded.shard_view(0)
    keys = [writer.insert(np.full(3, k, np.float32)) for k in range(6)]
    assert all(sharded.shard_of(k) == 0 for k in keys)
    assert sharded.shards[0].size() == 6
    assert sharded.shards[1].size() == 0
    assert sharded.size() == 6
    # front-end priority updates reach the owning shard through the key
    sharded.update_priorities(keys, [2.0] * len(keys))
    # shard-direct inserts never touched the front-end routing cursor
    assert sharded._insert_ticket.value == 0
    sharded.stop()


def test_routed_and_round_robin_inserts_sample_identically():
    """The agreement test: the same item stream written shard-directly
    (affinity) and through the front-end cursor (round_robin) produces the
    same global keys, the same shard contents, and — with the shards'
    deterministic selector streams — the same sampled batches."""
    routed = ShardedReplay.from_factory(_make_uniform_table, 2,
                                        routing="affinity")
    plain = ShardedReplay.from_factory(_make_uniform_table, 2,
                                       routing="round_robin")
    writers = [routed.shard_view(i) for i in range(2)]
    for k in range(16):
        data = np.full(3, k, np.float32)
        assert writers[k % 2].insert(data) == plain.insert(data)
    for (item_r, prob_r), (item_p, prob_p) in zip(routed.sample(8),
                                                  plain.sample(8)):
        assert item_r.key == item_p.key
        assert prob_r == prob_p
        np.testing.assert_array_equal(item_r.data, item_p.data)
    routed.stop()
    plain.stop()


def test_make_replay_shards_threads_routing_through():
    sharded = make_replay_shards(_make_uniform_table, 2, routing="affinity")
    assert isinstance(sharded, ShardedReplay)
    assert sharded.routing == "affinity"
    with pytest.raises(ValueError, match="routing"):
        ShardedReplay(sharded.shards, routing="sticky")
    sharded.stop()


def test_shard_writer_pickles_without_local_metric():
    writer = ShardWriter(_DummyShard(), shard_idx=1, num_shards=2)
    writer.insert(np.zeros(3))
    clone = pickle.loads(pickle.dumps(writer))
    assert (clone.shard_idx, clone.num_shards) == (1, 2)
    assert clone.insert(np.zeros(3)) == 1 * 2 + 1   # local key 1, shard 1


def test_run_experiment_affinity_async_end_to_end_with_telemetry():
    """One ExperimentConfig drives the whole tentpole: async learner
    replicas + shard-affine vectorized adders, with the routing counters
    proving every insert went shard-direct and the push/pull staleness
    histograms populated."""
    from repro.experiments import run_experiment

    config = make_dqn_catch_config(
        seed=0, min_replay_size=16, samples_per_insert=0.0, batch_size=16,
        prioritized=False, num_episodes=12, eval_episodes=0,
        num_envs_per_actor=2, num_learner_replicas=2,
        learner_average_period=5, learner_sync="async",
        replay_routing="affinity", telemetry=True)
    result = run_experiment(config)
    assert result.learner_steps > 0
    learners = result.extras["learners"]
    assert learners["sync"] == "async"
    assert learners["num_replicas"] == 2
    assert learners["rounds"] >= 1
    assert learners["service"]["pushes"] > 0
    merged = result.extras["telemetry"]["merged"]
    # both shards took shard-direct writes (env e -> shard e % 2)
    assert merged["replay/routing/shard_0/inserts"]["value"] > 0
    assert merged["replay/routing/shard_1/inserts"]["value"] > 0
    # the async exchange telemetry is live
    assert merged["learner/push_staleness"]["count"] > 0
    assert merged["learner/pull_age_steps"]["count"] > 0


# ------------------------------------------------------ program placement
def test_make_distributed_agent_places_async_param_service():
    from repro.agents.builders import make_distributed_agent
    from conftest import DQNCatchBuilderFactory, catch_env_factory

    builder = DQNCatchBuilderFactory(samples_per_insert=0.0)(_catch_spec())
    dist = make_distributed_agent(builder, catch_env_factory, num_actors=1,
                                  seed=0, num_learner_replicas=2,
                                  learner_average_period=10,
                                  learner_sync="async", prefetch_size=2)
    try:
        names = {n.name for n in dist.program.nodes}
        assert "learner/param_service" in names
        assert "learner/param_server" not in names
        node = dist.program.node("learner/param_service")
        assert node.interface == ASYNC_PARAM_SERVICE_INTERFACE
        assert isinstance(dist.learner, MultiLearner)
        service = dist.program.resolve("learner/param_service")
        assert dist.learner.async_service is service
        # replica workers run push/pull against the shared service
        for i in range(2):
            worker = dist.program.resolve(f"learner/replica_{i}")
            assert worker.sync_mode == "async"
            assert worker.param_server is service
    finally:
        dist.stop()
    assert all(d.closed for d in dist.datasets)


# --------------------------------------------------- learning acceptance
@pytest.mark.parametrize("launcher", [
    "local",
    pytest.param("multiprocess", marks=pytest.mark.slow),
])
def test_two_replica_async_dqn_on_catch_learns(launcher):
    """Acceptance: learner_sync='async' trains DQN-on-Catch through the
    UNCHANGED DQNBuilder on both backends — two free-running replica SGD
    streams exchanging through the push/pull service clear the eval bar,
    and extras['learners'] reports the async exchange stats."""
    from repro.experiments import run_distributed_experiment

    config = make_dqn_catch_config(
        seed=0, eval_episodes=20, launcher=launcher,
        num_learner_replicas=2, learner_average_period=10,
        learner_sync="async")
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=4000,
                                        timeout_s=240)
    assert result.counts.get("actor_steps", 0) >= 4000
    assert result.learner_steps > 50
    learners = result.extras["learners"]
    assert learners["num_replicas"] == 2
    assert learners["sync"] == "async"
    assert learners["rounds"] >= 1
    assert learners["service"]["pushes"] >= 2
    assert all(s > 0 for s in learners["per_replica_steps"])
    # both shards fed their replica
    per_shard = result.extras["replay"]["per_shard"]
    assert len(per_shard) == 2
    assert all(s["samples"] > 0 for s in per_shard)
    # learning: greedy eval beats the random-policy floor on Catch
    assert result.final_eval_return is not None
    assert result.final_eval_return > CATCH_FLOOR
