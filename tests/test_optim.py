import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim


def test_adam_minimizes_quadratic():
    opt = optim.adam(0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])))(params)
        updates, state = opt.update(grads, state, params)
        params = optim.apply_updates(params, updates)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adam_first_step_size_is_lr():
    opt = optim.adam(0.01)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    updates, _ = opt.update({"w": jnp.array([123.0])}, state, params)
    # bias correction makes the first step exactly lr * sign(grad)
    assert float(updates["w"][0]) == pytest.approx(-0.01, rel=1e-4)


def test_clipping_bounds_update_norm():
    opt = optim.sgd(1.0, clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    big = {"w": jnp.full(4, 100.0)}
    updates, _ = opt.update(big, state, params)
    assert float(optim.global_norm(updates)) <= 1.0 + 1e-5


def test_cosine_schedule_endpoints():
    sched = optim.cosine_schedule(1.0, total_steps=100, warmup_steps=10)
    assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


def test_periodic_update_copies_on_period():
    online = {"w": jnp.array([2.0])}
    target = {"w": jnp.array([1.0])}
    out = optim.periodic_update(online, target, jnp.asarray(10), 5)
    assert float(out["w"][0]) == 2.0
    out = optim.periodic_update(online, target, jnp.asarray(11), 5)
    assert float(out["w"][0]) == 1.0


def test_incremental_update_ema():
    online = {"w": jnp.array([1.0])}
    target = {"w": jnp.array([0.0])}
    out = optim.incremental_update(online, target, tau=0.1)
    assert float(out["w"][0]) == pytest.approx(0.1)
