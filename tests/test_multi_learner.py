"""Multi-learner training: per-shard learner replicas + parameter averaging.

The credibility net for ``repro.learners``:

- averaging math against a hand-computed pytree mean (params AND optimizer
  moments AND integer step counters);
- 1-replica multi-learner vs the plain learner — allclose (in fact equal)
  params from the same seed on identical sampled batches, both at the
  learner level and through ``run_experiment``;
- 2-replica DQN-on-Catch learns (mean eval return clears the random-policy
  floor) under both the ``local`` and ``multiprocess`` launchers — the
  acceptance criterion, driven through the UNCHANGED ``DQNBuilder``;
- program-graph placement: ``learner/replica_i`` nodes with shard affinity,
  the ``learner/param_server`` rendezvous, and the unchanged ``learner``
  variable endpoint;
- checkpoint round-trip of the merged state.

Factories come from ``conftest`` so the multiprocess backend can pickle
them into spawn children.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import make_dqn_catch_config
from repro.core import make_environment_spec
from repro.envs import Catch
from repro.learners import (LearnerReplicaWorker, MultiLearner,
                            ParameterServer, average_states)
from repro.replay.dataset import ReplaySample, SampleInfo

CATCH_FLOOR = -0.6   # random policy mean return on Catch is ~-1..-0.6


# ----------------------------------------------------------------- helpers
def _catch_spec():
    return make_environment_spec(Catch(seed=0))


def _dqn_builder(seed=0, **overrides):
    from repro.agents.dqn import DQNBuilder, DQNConfig
    kwargs = dict(min_replay_size=8, samples_per_insert=0.0, batch_size=8,
                  n_step=1, prioritized=False)
    kwargs.update(overrides)
    return DQNBuilder(_catch_spec(), DQNConfig(**kwargs), seed=seed)


def _synthetic_batches(num_batches, batch_size=8, seed=0):
    """Deterministic DQN-shaped ReplaySample batches (Catch observations)."""
    from repro.core.types import Transition
    rng = np.random.RandomState(seed)
    batches = []
    for b in range(num_batches):
        obs = rng.rand(batch_size, 10, 5).astype(np.float32)
        next_obs = rng.rand(batch_size, 10, 5).astype(np.float32)
        data = Transition(
            observation=obs,
            action=rng.randint(0, 3, size=batch_size).astype(np.int32),
            reward=rng.randn(batch_size).astype(np.float32),
            discount=np.ones(batch_size, np.float32),
            next_observation=next_obs)
        info = SampleInfo(np.arange(batch_size, dtype=np.int64),
                          np.full(batch_size, 1.0 / 64))
        batches.append(ReplaySample(info, data))
    return batches


def _tree_allclose(a, b, **kw):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(flat_a) == len(flat_b)
    for x, y in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


# ----------------------------------------------------- averaging math unit
def test_average_states_matches_hand_computed_pytree_mean():
    """Params, optimizer moments, and integer step counters are all
    element-wise averaged; dtypes are preserved."""
    s1 = {"params": {"w": jnp.array([1.0, 3.0]), "b": jnp.array(2.0)},
          "opt": {"mu": jnp.array([0.5, 0.5]), "nu": jnp.array([4.0, 0.0])},
          "steps": jnp.array(10, jnp.int32)}
    s2 = {"params": {"w": jnp.array([3.0, 5.0]), "b": jnp.array(6.0)},
          "opt": {"mu": jnp.array([1.5, 0.5]), "nu": jnp.array([0.0, 2.0])},
          "steps": jnp.array(10, jnp.int32)}
    merged = average_states([s1, s2])
    np.testing.assert_allclose(merged["params"]["w"], [2.0, 4.0])
    np.testing.assert_allclose(merged["params"]["b"], 4.0)
    np.testing.assert_allclose(merged["opt"]["mu"], [1.0, 0.5])
    np.testing.assert_allclose(merged["opt"]["nu"], [2.0, 1.0])
    assert merged["steps"] == 10
    assert merged["steps"].dtype == jnp.int32
    assert merged["params"]["w"].dtype == jnp.float32


def test_average_states_integer_counters_exact_past_float32_precision():
    """Step counters average in int64, not float32: equal counters above
    2^24 (where float32 rounds odd integers) must merge exactly — a long
    run's step counter cannot silently decrement at an averaging round."""
    big = 2 ** 24 + 1
    s1 = {"steps": jnp.array(big, jnp.int32)}
    s2 = {"steps": jnp.array(big, jnp.int32)}
    merged = average_states([s1, s2])
    assert int(merged["steps"]) == big
    assert merged["steps"].dtype == jnp.int32


def test_average_states_single_state_is_identity():
    """One replica: no float round-trip — the exact same pytree comes back
    (what makes the 1-replica configuration bit-equivalent)."""
    state = {"w": jnp.array([1.0, 2.0]), "steps": jnp.array(7, jnp.int32)}
    assert average_states([state]) is state


def test_average_states_on_real_learner_state_includes_opt_state():
    """The averaged LearnerState of two diverged DQN learners equals the
    hand-computed per-leaf mean, optimizer moments included."""
    batches = _synthetic_batches(4)
    l1 = _dqn_builder(seed=0).make_learner(iter(batches))
    l2 = _dqn_builder(seed=0).make_learner(iter(reversed(batches)))
    for _ in range(4):
        l1.step()
        l2.step()
    merged = average_states([l1.state, l2.state])
    hand = jax.tree.map(
        lambda a, b: ((np.asarray(a, np.float32) + np.asarray(b, np.float32))
                      / 2.0).astype(np.asarray(a).dtype),
        l1.state, l2.state)
    _tree_allclose(merged, hand, rtol=1e-6, atol=1e-7)


# ------------------------------------------------------- parameter server
def test_parameter_server_barrier_merges_and_counts_rounds():
    import threading
    server = ParameterServer(num_replicas=2, average_period=5)
    results = {}

    def contribute(rid, value):
        results[rid] = server.sync(rid, {"w": jnp.array(value)})

    t = threading.Thread(target=contribute, args=(0, 1.0))
    t.start()
    contribute(1, 3.0)
    t.join(5)
    assert not t.is_alive()
    np.testing.assert_allclose(results[0]["w"], 2.0)
    np.testing.assert_allclose(results[1]["w"], 2.0)
    assert server.rounds == 1
    assert server.stats() == {"num_replicas": 2, "average_period": 5,
                              "rounds": 1}


def test_parameter_server_stop_releases_blocked_sync():
    """A half-filled round must never wedge teardown: stop() wakes the
    blocked replica with None (it keeps its own state and exits)."""
    import threading
    server = ParameterServer(num_replicas=2, average_period=5)
    out = {}

    def blocked():
        out["result"] = server.sync(0, {"w": jnp.array(1.0)})

    t = threading.Thread(target=blocked)
    t.start()
    import time
    time.sleep(0.2)
    assert t.is_alive()
    server.stop()
    t.join(5)
    assert not t.is_alive()
    assert out["result"] is None
    assert server.rounds == 0


def test_parameter_server_rejects_bad_args():
    with pytest.raises(ValueError):
        ParameterServer(num_replicas=0, average_period=5)
    with pytest.raises(ValueError):
        ParameterServer(num_replicas=2, average_period=0)
    server = ParameterServer(num_replicas=2, average_period=5)
    with pytest.raises(ValueError):
        server.sync(2, {})


# ------------------------------------------------------------- parity net
def test_one_replica_multi_learner_matches_plain_learner():
    """The heart of the parity net: on IDENTICAL sampled batches from the
    same seed, a 1-replica MultiLearner and the plain learner produce
    allclose (equal) params, target params, and optimizer state."""
    batches = _synthetic_batches(12)
    plain = _dqn_builder(seed=3).make_learner(iter(list(batches)))
    multi = MultiLearner([_dqn_builder(seed=3).make_learner(
        iter(list(batches)))], average_period=4)
    for _ in range(12):
        plain.step()
        multi.step()
    _tree_allclose(multi.state.params, plain.state.params)
    _tree_allclose(multi.state.target_params, plain.state.target_params)
    _tree_allclose(multi.state.opt_state, plain.state.opt_state)
    assert int(multi.state.steps) == int(plain.state.steps) == 12
    # the served variables match too (one logical learner)
    _tree_allclose(multi.get_variables(("policy",))[0],
                   plain.get_variables(("policy",))[0])


def test_run_experiment_one_replica_parity_with_single_learner_path():
    """num_learner_replicas=1 routes through the multi-learner machinery
    and lands on exactly the same params as the default path — same seed,
    same env stream, same sampled batches."""
    from repro.experiments import run_experiment

    base = make_dqn_catch_config(
        seed=0, min_replay_size=16, samples_per_insert=0.0, batch_size=16,
        prioritized=False, num_episodes=15, eval_episodes=0)
    plain = run_experiment(base)
    multi = run_experiment(dataclasses.replace(
        base, num_learner_replicas=1, learner_average_period=7))
    assert plain.learner_steps == multi.learner_steps > 0
    _tree_allclose(multi.learner.state.params, plain.learner.state.params)
    _tree_allclose(multi.learner.state.opt_state,
                   plain.learner.state.opt_state)
    assert multi.extras["learners"]["num_replicas"] == 1
    assert multi.extras["learners"]["per_replica_steps"] == \
        [multi.learner_steps]
    assert "learners" not in plain.extras


def test_sequential_round_robin_averages_every_period():
    """2 replicas, period 3: after 6 facade steps (one full cycle of
    3-per-replica) every replica holds the merged state; counts and rounds
    are reported in stats()."""
    batches_a = _synthetic_batches(9, seed=1)
    batches_b = _synthetic_batches(9, seed=2)
    multi = MultiLearner(
        [_dqn_builder(seed=0).make_learner(iter(batches_a)),
         _dqn_builder(seed=0).make_learner(iter(batches_b))],
        average_period=3)
    for _ in range(5):
        multi.step()
    assert multi.param_server.rounds == 0     # mid-cycle: no merge yet
    multi.step()                              # completes 3 steps per replica
    assert multi.param_server.rounds == 1
    r0, r1 = multi.replicas
    _tree_allclose(r0.state.params, r1.state.params)
    stats = multi.stats()
    assert stats == {"num_replicas": 2, "average_period": 3, "rounds": 1,
                     "per_replica_steps": [3, 3]}


# ------------------------------------------------------ program placement
def test_make_distributed_agent_places_replica_nodes_with_shard_affinity():
    from repro.agents.builders import make_distributed_agent
    from conftest import DQNCatchBuilderFactory, catch_env_factory

    builder = DQNCatchBuilderFactory(samples_per_insert=0.0)(_catch_spec())
    dist = make_distributed_agent(builder, catch_env_factory, num_actors=1,
                                  seed=0, num_learner_replicas=2,
                                  learner_average_period=10,
                                  prefetch_size=2)
    try:
        names = {n.name for n in dist.program.nodes}
        assert {"learner", "learner/param_server", "learner/replica_0",
                "learner/replica_1", "replay/shard_0",
                "replay/shard_1"} <= names
        assert isinstance(dist.learner, MultiLearner)
        # shard affinity: replica i consumes exactly replay/shard_i
        for i in range(2):
            worker = dist.program.resolve(f"learner/replica_{i}")
            assert worker.shard is dist.table.shards[i]
        # the learner endpoint's declared interface is unchanged
        assert dist.program.node("learner").interface == ("get_variables",)
    finally:
        dist.stop()
    # replica teardown closed the per-replica prefetching datasets
    assert all(d.closed for d in dist.datasets)


def test_mismatched_shards_and_replicas_rejected():
    from repro.agents.builders import make_agent
    from conftest import DQNCatchBuilderFactory

    builder = DQNCatchBuilderFactory()(_catch_spec())
    with pytest.raises(ValueError, match="shard affinity"):
        make_agent(builder, num_learner_replicas=2, num_replay_shards=3)


def test_offline_builder_rejects_explicit_replicas():
    """An offline builder asked for replicas must fail loudly, not silently
    downgrade to one plain learner."""
    from repro.agents.bc import BCBuilder, BCConfig
    from repro.agents.builders import make_agent
    from repro.core.types import Transition

    items = [Transition(np.zeros((10, 5), np.float32), np.int32(i % 3),
                        np.float32(0.0), np.float32(1.0),
                        np.zeros((10, 5), np.float32)) for i in range(8)]
    builder = BCBuilder(_catch_spec(), items, BCConfig(batch_size=4), seed=0)
    with pytest.raises(ValueError, match="offline"):
        make_agent(builder, num_learner_replicas=2)


def test_consuming_queue_builder_runs_multi_learner_without_hanging():
    """IMPALA's replay is a consuming Fifo queue: the lockstep schedule
    must gate each sequential replica step on THAT replica's shard (the
    aggregate view can hold a batch the cursor's shard cannot serve, which
    would hang the loop inside a blocking sample)."""
    from repro.agents.impala import IMPALABuilder, IMPALAConfig
    from repro.experiments import ExperimentConfig, run_experiment

    config = ExperimentConfig(
        builder_factory=lambda spec: IMPALABuilder(
            spec, IMPALAConfig(sequence_length=3, batch_size=2), seed=0),
        environment_factory=lambda s: Catch(seed=s),
        seed=0, num_episodes=12, eval_episodes=0,
        num_learner_replicas=2, learner_average_period=2)
    result = run_experiment(config)
    assert result.learner_steps > 0
    assert result.extras["learners"]["num_replicas"] == 2


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_of_merged_state(tmp_path):
    """Checkpointing sees ONE logical learner: the saved state is the
    merged view, and restoring broadcasts it to every replica."""
    from repro.checkpoint import Checkpointer

    multi = MultiLearner(
        [_dqn_builder(seed=0).make_learner(iter(_synthetic_batches(4, seed=1))),
         _dqn_builder(seed=0).make_learner(iter(_synthetic_batches(4, seed=2)))],
        average_period=100)   # no merge before the save: replicas diverged
    for _ in range(8):
        multi.step()
    merged = multi.state
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(merged, 4)

    fresh = MultiLearner(
        [_dqn_builder(seed=9).make_learner(iter(_synthetic_batches(1))),
         _dqn_builder(seed=9).make_learner(iter(_synthetic_batches(1)))],
        average_period=100)
    restored, meta = ckpt.restore(fresh.state)
    assert meta["step"] == 4
    fresh.state = restored
    for replica in fresh.replicas:
        _tree_allclose(replica.state.params, merged.params,
                       rtol=1e-6, atol=1e-7)
        _tree_allclose(replica.state.opt_state, merged.opt_state,
                       rtol=1e-6, atol=1e-7)


# ----------------------------------------------------- prefetch teardown
def test_prefetching_dataset_close_joins_threads_and_drains():
    """close() = stop + join + drain: no sampler thread survives, no batch
    stays buffered — what replica teardown relies on to avoid leaking
    threads across sequential runs in one process."""
    import threading

    from repro.replay import MinSize, PrefetchingDataset, Table, Uniform

    table = Table("t", 100, Uniform(0), MinSize(1))
    for i in range(32):
        table.insert(np.full(3, i, np.float32))
    dataset = PrefetchingDataset(table, batch_size=4, prefetch_size=4,
                                 num_threads=2)
    next(dataset)
    assert not dataset.closed
    dataset.close()
    assert dataset.closed
    assert dataset.qsize() == 0
    assert all(not t.is_alive() for t in dataset._threads)
    dataset.close()   # idempotent
    table.stop()


def test_sequential_distributed_runs_do_not_accumulate_prefetch_threads():
    """Two back-to-back multi-learner runs with prefetching leave no
    sampler threads behind (the leak the explicit close() exists to stop)."""
    import threading

    from repro.experiments import run_distributed_experiment

    def live_prefetch_threads():
        return [t for t in threading.enumerate()
                if t.name.startswith("prefetch_") and t.is_alive()]

    config = make_dqn_catch_config(
        seed=0, samples_per_insert=0.0, eval_episodes=0,
        num_learner_replicas=2, learner_average_period=5, prefetch_size=2)
    for _ in range(2):
        result = run_distributed_experiment(config, num_actors=1,
                                            max_actor_steps=150,
                                            timeout_s=60)
        assert result.learner_steps >= 0
    import time
    deadline = time.time() + 5
    while live_prefetch_threads() and time.time() < deadline:
        time.sleep(0.1)
    assert not live_prefetch_threads()


# --------------------------------------------------- learning acceptance
@pytest.mark.parametrize("launcher", [
    "local",
    pytest.param("multiprocess", marks=pytest.mark.slow),
])
def test_two_replica_dqn_on_catch_learns(launcher):
    """Acceptance: run_distributed_experiment(num_learner_replicas=2)
    trains DQN-on-Catch through the UNCHANGED DQNBuilder on both backends —
    two replica SGD streams with parameter averaging clear the eval bar,
    and extras['learners'] reports per-replica steps + averaging rounds."""
    from repro.experiments import run_distributed_experiment

    config = make_dqn_catch_config(
        seed=0, eval_episodes=20, launcher=launcher,
        num_learner_replicas=2, learner_average_period=10)
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=4000,
                                        timeout_s=240)
    assert result.counts.get("actor_steps", 0) >= 4000
    assert result.learner_steps > 50
    learners = result.extras["learners"]
    assert learners["num_replicas"] == 2
    assert learners["rounds"] >= 1
    assert all(s > 0 for s in learners["per_replica_steps"])
    # both shards fed their replica
    per_shard = result.extras["replay"]["per_shard"]
    assert len(per_shard) == 2
    assert all(s["samples"] > 0 for s in per_shard)
    # learning: greedy eval beats the random-policy floor on Catch
    assert result.final_eval_return is not None
    assert result.final_eval_return > CATCH_FLOOR
