"""Batched acting pipeline: VectorEnv + batched actors + vectorized loop +
the SEED-style InferenceServer.

The parity net proves a ``VectorizedEnvironmentLoop`` with N=4 Catch envs
produces the same counter totals / adder streams as 4 sequential single-env
loops, and a learning curve statistically equivalent to the single-env run;
the inference net proves ``inference="server"`` trains DQN-on-Catch under
the multiprocess launcher with coalesced batches.

Factories are module-level so the multiprocess backend can pickle them.
"""
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (BatchedFeedForwardActor, Counter, EnvironmentLoop,
                        InferenceServer, StepType, VariableClient,
                        VectorizedEnvironmentLoop, make_environment_spec)
from repro.core.actors import adder_takes_extras
from repro.envs import Catch, VectorEnv, split_timestep


# ---------------------------------------------------------------- fixtures
class _ParamSource:
    def get_variables(self, names=()):
        return [{"w": np.float32(1.0)}]


# Shared DQN-on-Catch smoke factories (conftest): picklable, so the
# multiprocess backend can ship them into actor children.
from conftest import DQNCatchBuilderFactory  # noqa: E402
from conftest import catch_env_factory as _mp_env_factory  # noqa: E402

_dqn_builder = DQNCatchBuilderFactory(samples_per_insert=0.0, batch_size=32)
_mp_builder_factory = DQNCatchBuilderFactory()


# ---------------------------------------------------------------- VectorEnv
def test_vector_env_stacks_and_auto_resets():
    venv = VectorEnv(lambda s: Catch(seed=s), 3, seed=0)
    ts = venv.reset()
    assert ts.observation.shape == (3, 10, 5)
    assert ts.step_type.shape == (3,)
    assert all(int(t) == StepType.FIRST for t in ts.step_type)

    # Catch episodes are exactly rows-1 = 9 steps long
    for _ in range(9):
        ts = venv.step(np.ones(3, np.int32))
    assert all(int(t) == StepType.LAST for t in ts.step_type)
    # auto-reset: next step restarts every env, action ignored
    ts = venv.step(np.zeros(3, np.int32))
    assert all(int(t) == StepType.FIRST for t in ts.step_type)
    # and then stepping continues normally
    ts = venv.step(np.zeros(3, np.int32))
    assert all(int(t) == StepType.MID for t in ts.step_type)


def test_vector_env_specs_are_per_env():
    venv = VectorEnv(lambda s: Catch(seed=s), 4, seed=0)
    spec = make_environment_spec(venv)
    assert spec.observations.shape == (10, 5)   # single-env view
    assert spec.actions.num_values == 3


def test_split_timestep_restores_dm_env_convention():
    venv = VectorEnv(lambda s: Catch(seed=s), 2, seed=0)
    ts = venv.reset()
    first = split_timestep(ts, 0)
    assert first.first() and first.reward is None and first.discount is None
    ts = venv.step(np.zeros(2, np.int32))
    mid = split_timestep(ts, 1)
    assert mid.mid() and isinstance(mid.reward, float)


def test_vector_env_wrong_action_count_rejected():
    venv = VectorEnv(lambda s: Catch(seed=s), 2, seed=0)
    venv.reset()
    with pytest.raises(ValueError, match="expected 2 actions"):
        venv.step(np.zeros(3, np.int32))


# ----------------------------------------------------- loop parity (tier 1)
class _ScriptedBatchedActor:
    """Deterministic batched actor: same per-env action stream as the
    scripted single actor below, routed to per-env adders."""

    def __init__(self, adders):
        self._adders = adders
        self.updates = 0

    def select_action(self, observation):
        return np.asarray([1] * observation.shape[0], np.int32)

    def observe_first(self, timestep, env_id=0):
        if self._adders[env_id]:
            self._adders[env_id].add_first(timestep)

    def observe(self, action, next_timestep, env_id=0):
        if self._adders[env_id]:
            self._adders[env_id].add(action, next_timestep)

    def update(self, wait=False):
        self.updates += 1


class _ScriptedSingleActor:
    def __init__(self, adder):
        self._adder = adder

    def select_action(self, observation):
        return np.int32(1)

    def observe_first(self, timestep):
        if self._adder:
            self._adder.add_first(timestep)

    def observe(self, action, next_timestep):
        if self._adder:
            self._adder.add(action, next_timestep)

    def update(self, wait=False):
        pass


def _fresh_table():
    from repro.replay import MinSize, Table, Uniform
    return Table("t", 10_000, Uniform(0), MinSize(1))


def test_vectorized_loop_matches_sequential_loops():
    """N=4 Catch envs in one vectorized loop == 4 sequential single-env
    loops: identical counter totals and identical per-env adder streams."""
    from repro.adders import NStepTransitionAdder

    num_envs, episodes_each = 4, 5

    # 4 sequential single-env loops, one adder each
    seq_table = _fresh_table()
    seq_counter = Counter()
    for i in range(num_envs):
        adder = NStepTransitionAdder(seq_table, 1, 0.99)
        loop = EnvironmentLoop(Catch(seed=i), _ScriptedSingleActor(adder),
                               counter=seq_counter, label="actor")
        loop.run(num_episodes=episodes_each)

    # one vectorized loop over the same 4 envs (VectorEnv seeds 0..3)
    vec_table = _fresh_table()
    vec_counter = Counter()
    adders = [NStepTransitionAdder(vec_table, 1, 0.99)
              for _ in range(num_envs)]
    vec_loop = VectorizedEnvironmentLoop(
        VectorEnv(lambda s: Catch(seed=s), num_envs, seed=0),
        _ScriptedBatchedActor(adders), counter=vec_counter, label="actor")
    results = vec_loop.run(num_episodes=num_envs * episodes_each)

    assert len(results) == num_envs * episodes_each
    assert vec_counter.get_counts() == seq_counter.get_counts()
    assert vec_counter.get_counts()["actor_steps"] == num_envs \
        * episodes_each * 9   # Catch episodes are 9 transitions
    # identical experience volume reached replay through the per-env adders
    assert vec_table.size() == seq_table.size()
    # same deterministic action script + same env seeds => same rewards
    seq_rewards = sorted(float(it.data.reward)
                         for it in seq_table._items.values())
    vec_rewards = sorted(float(it.data.reward)
                         for it in vec_table._items.values())
    assert seq_rewards == vec_rewards


def test_vectorized_loop_num_steps_counts_transitions():
    adders = [None] * 2
    loop = VectorizedEnvironmentLoop(
        VectorEnv(lambda s: Catch(seed=s), 2, seed=0),
        _ScriptedBatchedActor(adders), counter=Counter(), label="actor")
    loop.run(num_steps=20)   # stops at the first tick boundary >= 20


def test_vectorized_loop_resumes_in_flight_episodes():
    """Chunked run() calls continue in-flight episodes instead of resetting
    the envs: 9 calls of 1 step each complete exactly one 9-step episode
    per env, with no discarded partial episodes."""
    counter = Counter()
    loop = VectorizedEnvironmentLoop(
        VectorEnv(lambda s: Catch(seed=s), 2, seed=0),
        _ScriptedBatchedActor([None] * 2), counter=counter, label="actor")
    results = []
    for _ in range(9):
        results.extend(loop.run(num_steps=1))
    assert len(results) == 2   # both envs finished exactly one episode
    counts = counter.get_counts()
    assert counts["actor_episodes"] == 2
    assert counts["actor_steps"] == 18   # every transition counted once


def test_vectorized_run_experiment_respects_max_actor_steps():
    """max_actor_steps smaller than one episode must terminate (the loop
    resumes in-flight episodes across chunks rather than restarting them)."""
    from repro.experiments import ExperimentConfig, run_experiment

    config = ExperimentConfig(
        builder_factory=_dqn_builder,
        environment_factory=lambda seed: Catch(seed=seed),
        seed=0, num_episodes=1000, max_actor_steps=60, eval_episodes=0,
        num_envs_per_actor=4)
    result = run_experiment(config)
    total = sum(int(c) for c in
                [result.counts.get("actor_steps", 0)])
    assert total >= 60
    assert total < 600   # stopped promptly, not after 1000 episodes


# ------------------------------------------------------------ batched actors
def test_batched_actor_one_policy_trace_per_tick():
    calls = []

    def policy(params, key, obs):
        calls.append(1)   # traced once per vmapped call, not once per env
        return jnp.argmax(jnp.sum(obs, axis=-1)).astype(jnp.int32)

    client = VariableClient(_ParamSource())
    actor = BatchedFeedForwardActor(policy, client, adders=[None] * 8,
                                    jit=False)
    obs = np.random.rand(8, 10, 5).astype(np.float32)
    for _ in range(3):
        actions = actor.select_action(obs)
        assert actions.shape == (8,)
    assert len(calls) == 3


def test_batched_actor_rng_decorrelates_envs():
    """Per-env device keys: envs given identical observations must not all
    pick identical (exploring) actions."""
    spec = make_environment_spec(Catch(seed=0))
    builder = DQNCatchBuilderFactory(samples_per_insert=0.0, batch_size=32,
                                     epsilon=1.0)(spec)   # pure exploration
    learner = builder.make_learner(iter([]))
    actor = builder.make_batched_actor(
        builder.make_policy(evaluation=False),
        VariableClient(learner), [None] * 16, seed=0)
    obs = np.stack([Catch(seed=0).reset().observation] * 16)
    actions = np.concatenate([actor.select_action(obs) for _ in range(4)])
    assert len(set(actions.tolist())) > 1


def test_batched_recurrent_actor_resets_per_env_state():
    from repro.agents.r2d2 import R2D2Builder, R2D2Config
    spec = make_environment_spec(Catch(seed=0))
    builder = R2D2Builder(spec, R2D2Config(sequence_length=4, period=2,
                                           batch_size=4, min_replay_size=4,
                                           samples_per_insert=0.0), seed=0)
    learner = builder.make_learner(iter([]))
    table = _fresh_table()
    adders = [builder.make_adder(table) for _ in range(3)]
    actor = builder.make_batched_actor(builder.make_policy(False),
                                       VariableClient(learner), adders,
                                       seed=0)
    venv = VectorEnv(lambda s: Catch(seed=s), 3, seed=0)
    loop = VectorizedEnvironmentLoop(venv, actor, counter=Counter(),
                                     label="actor")
    loop.run(num_episodes=6)
    assert table.size() > 0   # sequences (with start-state extras) landed
    item = next(iter(table._items.values())).data
    assert "mask" in item     # stacked sequence dict from the SequenceAdder


# ------------------------------------------- satellite: extras capability
def test_adder_takes_extras_flags():
    from repro.adders import EpisodeAdder, NStepTransitionAdder, SequenceAdder
    table = _fresh_table()
    assert adder_takes_extras(SequenceAdder(table, 4, 2))
    assert not adder_takes_extras(NStepTransitionAdder(table, 1))
    assert not adder_takes_extras(EpisodeAdder(table))
    assert not adder_takes_extras(None)


def test_adder_takes_extras_signature_fallback():
    """An extras-capable Adder subclass that predates the supports_extras
    flag must still be detected via signature inspection (the base class
    deliberately does NOT declare a default that would shadow it)."""
    from repro.adders.base import Adder

    class LegacyExtrasAdder(Adder):
        def add_first(self, timestep, extras=()):
            pass

        def add(self, action, next_timestep, extras=()):
            pass

    class LegacyPlainAdder(Adder):
        def add_first(self, timestep):
            pass

        def add(self, action, next_timestep, extras=()):
            pass

    assert adder_takes_extras(LegacyExtrasAdder())
    assert not adder_takes_extras(LegacyPlainAdder())


def test_recurrent_actor_does_not_mask_adder_typeerrors():
    """A TypeError raised INSIDE the adder must propagate — the old
    try/except TypeError probing silently re-dispatched to the 1-arg
    overload instead."""
    from repro.core import RecurrentActor

    class BoomAdder:
        supports_extras = True

        def add_first(self, timestep, extras=()):
            raise TypeError("boom from inside the adder")

        def add(self, action, next_timestep, extras=()):
            pass

    spec = make_environment_spec(Catch(seed=0))
    actor = RecurrentActor(lambda p, k, o, s: (jnp.int32(0), s),
                           initial_state_fn=lambda: jnp.zeros((1, 2)),
                           variable_client=VariableClient(_ParamSource()),
                           adder=BoomAdder())
    with pytest.raises(TypeError, match="boom from inside the adder"):
        actor.observe_first(Catch(seed=0).reset())


# ------------------------------------------- satellite: loop update_period
class _CountingActor:
    def __init__(self):
        self.updates = 0

    def select_action(self, observation):
        return np.int32(0)

    def observe_first(self, timestep):
        pass

    def observe(self, action, next_timestep):
        pass

    def update(self, wait=False):
        self.updates += 1


def test_environment_loop_update_period():
    actor = _CountingActor()
    loop = EnvironmentLoop(Catch(seed=0), actor, counter=Counter(),
                           update_period=3)
    result = loop.run_episode()
    assert result["episode_length"] == 9
    assert actor.updates == 3   # every 3rd step, not all 9


def test_environment_loop_update_period_validated():
    with pytest.raises(ValueError, match="update_period"):
        EnvironmentLoop(Catch(seed=0), _CountingActor(), update_period=0)
    with pytest.raises(ValueError, match="update_period"):
        VectorizedEnvironmentLoop(
            VectorEnv(lambda s: Catch(seed=s), 2), _CountingActor(),
            update_period=0)


# ------------------------------------------------------- InferenceServer
def test_inference_server_coalesces_and_routes():
    policy = lambda params, key, obs: jnp.sum(obs) * params["w"]  # noqa: E731
    server = InferenceServer(policy, _ParamSource(), max_batch_size=32,
                             max_wait_ms=100.0)
    try:
        out = {}

        def call(i):
            obs = np.full((2, 3), float(i), np.float32)
            out[i] = np.asarray(server.select_action(obs))

        threads = [threading.Thread(target=call, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for i in range(8):
            assert np.allclose(out[i], 3.0 * i), (i, out[i])
        stats = server.stats()
        assert stats["rows"] == 16
        assert stats["requests"] == 8
        # concurrent requests coalesced into fewer forward passes
        assert stats["batches"] < stats["requests"]
    finally:
        server.stop()


def test_inference_server_respects_max_batch_rows():
    policy = lambda params, key, obs: jnp.sum(obs)  # noqa: E731
    server = InferenceServer(policy, _ParamSource(), max_batch_size=4,
                             max_wait_ms=1.0)
    try:
        with pytest.raises(ValueError, match="exceeds max_batch_size"):
            server.select_action(np.zeros((5, 2), np.float32))
        # a full sweep of smaller requests still lands
        r = server.select_action(np.ones((4, 2), np.float32))
        assert r.shape == (4,)
    finally:
        server.stop()


def test_inference_server_rejects_recurrent_policy():
    recurrent = lambda params, key, obs, state: (obs, state)  # noqa: E731
    with pytest.raises(ValueError, match="feed-forward"):
        InferenceServer(recurrent, _ParamSource())


@pytest.mark.parametrize("make", ["impala", "r2d2"])
def test_server_inference_rejects_extras_and_recurrent_builders(make):
    """Agents whose actors need per-step extras (IMPALA's behaviour logits)
    or recurrent state cannot run behind the weightless client — rejected
    at config time, not mid-run in the batcher thread."""
    from repro.agents.builders import make_distributed_agent

    spec = make_environment_spec(Catch(seed=0))
    if make == "impala":
        from repro.agents.impala import IMPALABuilder, IMPALAConfig
        builder = IMPALABuilder(spec, IMPALAConfig(sequence_length=3,
                                                   batch_size=2), seed=0)
    else:
        from repro.agents.r2d2 import R2D2Builder, R2D2Config
        builder = R2D2Builder(spec, R2D2Config(sequence_length=4, period=2,
                                               batch_size=4,
                                               min_replay_size=4), seed=0)
    with pytest.raises(ValueError, match="does not support"):
        make_distributed_agent(builder, _mp_env_factory, num_actors=1,
                               inference="server")


def test_inference_server_stop_raises_connection_error():
    policy = lambda params, key, obs: jnp.sum(obs)  # noqa: E731
    server = InferenceServer(policy, _ParamSource())
    server.stop()
    with pytest.raises(ConnectionError, match="stopped"):
        server.select_action(np.zeros((1, 2), np.float32))


# --------------------------------------------------- learning parity nets
def test_vectorized_dqn_learning_statistically_equivalent():
    """DQN-on-Catch through run_experiment with num_envs_per_actor=4 learns
    like the single-env run: both clear the same eval bar."""
    from repro.experiments import ExperimentConfig, run_experiment

    evals = {}
    for num_envs in (1, 4):
        config = ExperimentConfig(
            builder_factory=_dqn_builder,
            environment_factory=lambda seed: Catch(seed=seed),
            seed=0, num_episodes=150, eval_episodes=20,
            num_envs_per_actor=num_envs)
        result = run_experiment(config)
        assert len(result.train_returns) >= 150
        assert result.learner_steps > 0
        evals[num_envs] = result.final_eval_return
    # both runs beat the random-policy floor (~-0.6) by a wide margin —
    # the vectorized pipeline feeds the same learner the same data volume
    assert evals[1] > 0.0, evals
    assert evals[4] > 0.0, evals


@pytest.mark.slow
def test_server_inference_trains_dqn_multiprocess():
    """Acceptance: inference='server' trains DQN-on-Catch under the
    multiprocess launcher — actors in child processes RPC one parent-side
    InferenceServer that coalesces their select_action calls."""
    from repro.experiments import ExperimentConfig, run_distributed_experiment

    config = ExperimentConfig(
        builder_factory=_mp_builder_factory,
        environment_factory=_mp_env_factory,
        seed=0, eval_episodes=20, launcher="multiprocess",
        inference="server", num_envs_per_actor=2)
    result = run_distributed_experiment(config, num_actors=2,
                                        max_actor_steps=3000,
                                        timeout_s=240)
    assert result.counts.get("actor_steps", 0) >= 3000
    assert result.learner_steps > 50
    stats = result.extras["inference"]
    assert stats["batches"] > 0
    # coalescing happened: more rows than forward passes
    assert stats["rows"] > stats["batches"]
    # learning: greedy eval beats the random-policy floor on Catch
    assert result.final_eval_return is not None
    assert result.final_eval_return > -0.6
